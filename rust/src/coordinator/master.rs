//! The master: drives encoded rounds end-to-end (encode → seal →
//! dispatch → collect → decrypt → decode) and owns all accounting.

use super::messages::{ResultMsg, WirePayload, WorkOrder};
use super::pool::WorkerPool;
use crate::coding::{make_scheme, CodeParams, MatDot, Scheme};
use crate::config::{SchemeKind, SystemConfig, TransportSecurity};
use crate::ecc::{sim_curve, KeyPair, MaskMode, MeaEcc};
use crate::field::Fp61;
use crate::matrix::Matrix;
use crate::metrics::{names, MetricsRegistry};
use crate::rng::{derive_seed, rng_from_seed, Rng};
use crate::runtime::{Executor, WorkerOp};
use crate::sim::{CollusionPool, DelayModel, EavesdropLog};
use std::collections::HashMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Result of one coded round.
#[derive(Debug)]
pub struct RoundOutcome {
    /// Decoded per-block results `Yᵢ ≈ f(Xᵢ)` (for block-map rounds) or
    /// the single full product (MatDot rounds).
    pub blocks: Vec<Matrix>,
    /// Wall-clock for the whole round (dispatch → decode done).
    pub wall: Duration,
    /// How many worker results the decoder consumed.
    pub results_used: usize,
}

/// Builder for [`Master`].
pub struct MasterBuilder {
    cfg: SystemConfig,
    executor: Option<Executor>,
    eavesdropper: Option<Arc<EavesdropLog>>,
    collusion: Option<Arc<CollusionPool>>,
    metrics: Option<Arc<MetricsRegistry>>,
}

impl MasterBuilder {
    /// Start from a config.
    pub fn new(cfg: SystemConfig) -> Self {
        Self { cfg, executor: None, eavesdropper: None, collusion: None, metrics: None }
    }

    /// Attach an executor (default: native with fresh metrics).
    pub fn executor(mut self, e: Executor) -> Self {
        self.executor = Some(e);
        self
    }

    /// Attach an eavesdropper tap.
    pub fn eavesdropper(mut self, tap: Arc<EavesdropLog>) -> Self {
        self.eavesdropper = Some(tap);
        self
    }

    /// Attach a collusion pool (its members leak their shares).
    pub fn collusion(mut self, pool: Arc<CollusionPool>) -> Self {
        self.collusion = Some(pool);
        self
    }

    /// Share a metrics registry.
    pub fn metrics(mut self, m: Arc<MetricsRegistry>) -> Self {
        self.metrics = Some(m);
        self
    }

    /// Spawn the worker pool and build the master.
    pub fn build(self) -> anyhow::Result<Master> {
        self.cfg.validate().map_err(|e| anyhow::anyhow!(e.to_string()))?;
        let metrics = self.metrics.unwrap_or_else(|| Arc::new(MetricsRegistry::new()));
        let executor =
            self.executor.unwrap_or_else(|| Executor::native(Arc::clone(&metrics)));
        let curve = sim_curve();
        let mut rng = rng_from_seed(derive_seed(self.cfg.seed, 0x3A57E2));
        let keys = KeyPair::generate(&curve, &mut rng);
        let pool = WorkerPool::spawn(
            self.cfg.workers,
            keys.public(),
            executor,
            self.collusion.clone(),
            self.cfg.seed,
        );
        let params =
            CodeParams::new(self.cfg.workers, self.cfg.partitions, self.cfg.colluders);
        let (scheme, matdot) = match self.cfg.scheme {
            SchemeKind::MatDot => (None, Some(MatDot::new(self.cfg.workers, self.cfg.partitions))),
            kind => (make_scheme(kind, params), None),
        };
        let delays = DelayModel::new(
            self.cfg.workers,
            self.cfg.stragglers,
            self.cfg.delay,
            self.cfg.seed,
        );
        Ok(Master {
            cfg: self.cfg,
            scheme,
            matdot,
            pool,
            keys,
            mea: MeaEcc::new(curve, MaskMode::Keystream),
            metrics,
            eavesdropper: self.eavesdropper,
            delays,
            round: 0,
            rng,
            outstanding: HashMap::new(),
        })
    }
}

/// The master node.
pub struct Master {
    cfg: SystemConfig,
    scheme: Option<Box<dyn Scheme>>,
    matdot: Option<MatDot>,
    pool: WorkerPool,
    keys: KeyPair<Fp61>,
    mea: MeaEcc<Fp61>,
    metrics: Arc<MetricsRegistry>,
    eavesdropper: Option<Arc<EavesdropLog>>,
    delays: DelayModel,
    round: u64,
    rng: Rng,
    /// round → results still in flight (late-arrival accounting).
    outstanding: HashMap<u64, usize>,
}

impl Master {
    /// Convenience: build with defaults from a config.
    pub fn from_config(cfg: SystemConfig) -> anyhow::Result<Self> {
        MasterBuilder::new(cfg).build()
    }

    /// The metrics registry.
    pub fn metrics(&self) -> &Arc<MetricsRegistry> {
        &self.metrics
    }

    /// The active config.
    pub fn config(&self) -> &SystemConfig {
        &self.cfg
    }

    /// The straggler set chosen for this scenario.
    pub fn straggler_set(&self) -> Vec<usize> {
        self.delays.straggler_set()
    }

    /// Run one block-map round: distribute `f = op` over the row-blocks
    /// of `x` with the configured scheme, return `{Yᵢ ≈ f(Xᵢ)}`.
    pub fn run_blockmap(&mut self, op: WorkerOp, x: &Matrix) -> anyhow::Result<RoundOutcome> {
        let scheme = self
            .scheme
            .take()
            .ok_or_else(|| anyhow::anyhow!("configured scheme is a pair code; use run_matmul"))?;
        let result = self.run_blockmap_with(&*scheme, op, x);
        self.scheme = Some(scheme);
        result
    }

    fn run_blockmap_with(
        &mut self,
        scheme: &dyn Scheme,
        op: WorkerOp,
        x: &Matrix,
    ) -> anyhow::Result<RoundOutcome> {
        let deg = op.degree();
        if !scheme.supports_degree(deg) {
            anyhow::bail!("{} does not support degree-{deg} tasks", scheme.kind().name());
        }
        self.drain_stale();
        self.round += 1;
        let round = self.round;
        let t0 = Instant::now();

        // Phase 1: encode (+T masks) — §V-B "data process".
        let encoded = {
            let _t = self.metrics.time_phase("phase.encode");
            scheme.encode(x, deg, &mut self.rng)?
        };

        // Dispatch sealed shares.
        {
            let metrics = Arc::clone(&self.metrics);
            let _t = metrics.time_phase("phase.dispatch");
            for (w, share) in encoded.shares.iter().enumerate() {
                let payload = self.seal_for(w, share);
                self.capture(w, true, &payload);
                self.metrics.add(names::SYMBOLS_TO_WORKERS, payload.symbols() as u64);
                self.metrics.inc(names::TASKS_DISPATCHED);
                self.pool.dispatch(WorkOrder {
                    round,
                    worker: w,
                    op: op.clone(),
                    payloads: vec![payload],
                    delay: self.delays.service_delay(w, round),
                });
            }
        }

        // Phase 3: collect + decode.
        let wait = self.wait_count(scheme.threshold(deg));
        let results = self.collect(round, wait, self.cfg.workers)?;
        let used = results.len();
        let decoded = {
            let _t = self.metrics.time_phase("phase.decode");
            scheme.decode(&encoded.ctx, &results)?
        };
        Ok(RoundOutcome { blocks: decoded, wall: t0.elapsed(), results_used: used })
    }

    /// Run one MatDot round: the full product `A·B` via the pair code.
    pub fn run_matmul(&mut self, a: &Matrix, b: &Matrix) -> anyhow::Result<RoundOutcome> {
        let code = self
            .matdot
            .clone()
            .ok_or_else(|| anyhow::anyhow!("configured scheme is not MatDot; use run_blockmap"))?;
        let code = &code;
        self.drain_stale();
        self.round += 1;
        let round = self.round;
        let t0 = Instant::now();

        let encoded = {
            let _t = self.metrics.time_phase("phase.encode");
            code.encode_pair(a, b)?
        };

        {
            let metrics = Arc::clone(&self.metrics);
            let _t = metrics.time_phase("phase.dispatch");
            for (w, (pa, pb)) in encoded.shares.iter().enumerate() {
                let payload_a = self.seal_for(w, pa);
                let payload_b = self.seal_for(w, pb);
                for p in [&payload_a, &payload_b] {
                    self.capture(w, true, p);
                    self.metrics.add(names::SYMBOLS_TO_WORKERS, p.symbols() as u64);
                }
                self.metrics.inc(names::TASKS_DISPATCHED);
                self.pool.dispatch(WorkOrder {
                    round,
                    worker: w,
                    op: WorkerOp::PairProduct,
                    payloads: vec![payload_a, payload_b],
                    delay: self.delays.service_delay(w, round),
                });
            }
        }

        let results = self.collect(round, code.threshold(), self.cfg.workers)?;
        let used = results.len();
        let product = {
            let _t = self.metrics.time_phase("phase.decode");
            code.decode(&encoded, &results)?
        };
        Ok(RoundOutcome { blocks: vec![product], wall: t0.elapsed(), results_used: used })
    }

    /// How many results to wait for, given the scheme's threshold.
    fn wait_count(&self, threshold: crate::coding::Threshold) -> usize {
        match threshold {
            crate::coding::Threshold::Exact(k) => k,
            // Flexible: take what the non-stragglers produce (paper's
            // experimental policy — decode fires when the fast workers
            // are in, without waiting out the stragglers).
            crate::coding::Threshold::Flexible { min } => {
                (self.cfg.workers - self.cfg.stragglers).max(min)
            }
        }
    }

    /// Collect `wait` results for `round`, unsealing payloads.
    fn collect(
        &mut self,
        round: u64,
        wait: usize,
        dispatched: usize,
    ) -> anyhow::Result<Vec<(usize, Matrix)>> {
        let metrics = Arc::clone(&self.metrics);
        let _t = metrics.time_phase("phase.wait");
        let mut results = Vec::with_capacity(wait);
        let deadline = Duration::from_secs(60);
        while results.len() < wait {
            let msg: ResultMsg = self
                .pool
                .results()
                .recv_timeout(deadline)
                .map_err(|_| anyhow::anyhow!("timed out waiting for worker results"))?;
            if msg.round != round {
                self.note_stale(msg.round);
                continue;
            }
            self.capture(msg.worker, false, &msg.payload);
            self.metrics.add(names::SYMBOLS_TO_MASTER, msg.payload.symbols() as u64);
            self.metrics.inc(names::RESULTS_USED);
            let m = self.unseal(&msg.payload);
            results.push((msg.worker, m));
        }
        // Anything not yet received is in flight → counted late when it
        // lands during a later round (or drained on the next round).
        self.outstanding.insert(round, dispatched - results.len());
        Ok(results)
    }

    /// Seal (or pass through) a share for worker `w`.
    fn seal_for(&mut self, w: usize, m: &Matrix) -> WirePayload {
        match self.cfg.transport {
            TransportSecurity::Plain => WirePayload::Plain(m.clone()),
            TransportSecurity::MeaEcc => WirePayload::Sealed(self.mea.encrypt(
                m,
                &self.pool.worker_pks()[w],
                &mut self.rng,
            )),
        }
    }

    /// Unseal a worker result.
    fn unseal(&self, p: &WirePayload) -> Matrix {
        match p {
            WirePayload::Plain(m) => m.clone(),
            WirePayload::Sealed(s) => self.mea.decrypt(s, &self.keys),
        }
    }

    /// Record an eavesdropped wire payload.
    fn capture(&self, worker: usize, downlink: bool, p: &WirePayload) {
        if let Some(tap) = &self.eavesdropper {
            tap.capture(worker, downlink, p.wire_view());
        }
    }

    /// Drain results from previous rounds that arrived after decode.
    fn drain_stale(&mut self) {
        while let Ok(msg) = self.pool.results().try_recv() {
            self.note_stale(msg.round);
        }
    }

    fn note_stale(&mut self, round: u64) {
        self.metrics.inc(names::RESULTS_LATE);
        if let Some(left) = self.outstanding.get_mut(&round) {
            *left = left.saturating_sub(1);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::{matmul, split_rows};

    fn base_cfg(scheme: SchemeKind) -> SystemConfig {
        let mut cfg = SystemConfig::default();
        cfg.workers = 12;
        cfg.partitions = 3;
        cfg.colluders = 2;
        cfg.stragglers = 2;
        cfg.scheme = scheme;
        cfg.delay.base_service_s = 0.0; // fast tests
        cfg
    }

    #[test]
    fn spacdc_round_end_to_end_sealed() {
        let mut master = Master::from_config(base_cfg(SchemeKind::Spacdc)).unwrap();
        let mut rng = rng_from_seed(1);
        let x = Matrix::random_gaussian(24, 8, 0.0, 1.0, &mut rng);
        let v = Arc::new(Matrix::random_gaussian(8, 4, 0.0, 1.0, &mut rng));
        let out = master
            .run_blockmap(WorkerOp::RightMul(Arc::clone(&v)), &x)
            .unwrap();
        assert_eq!(out.blocks.len(), 3);
        assert_eq!(out.results_used, 10); // N − S
        let (blocks, _) = split_rows(&x, 3);
        for (d, b) in out.blocks.iter().zip(&blocks) {
            let err = d.rel_error(&matmul(b, &v));
            // Approximate decode at N=12, S=2, with privacy masks: the
            // bound here is coarse; accuracy-vs-returns is characterized
            // precisely in the coding-layer tests.
            assert!(err < 0.5, "err={err}");
        }
        // Transport accounting is live.
        assert!(master.metrics().get(names::SYMBOLS_TO_WORKERS) > 0);
        assert!(master.metrics().get(names::SYMBOLS_TO_MASTER) > 0);
    }

    #[test]
    fn mds_round_exact_decode() {
        let mut cfg = base_cfg(SchemeKind::Mds);
        cfg.transport = TransportSecurity::Plain;
        let mut master = Master::from_config(cfg).unwrap();
        let mut rng = rng_from_seed(2);
        let x = Matrix::random_gaussian(24, 6, 0.0, 1.0, &mut rng);
        let v = Arc::new(Matrix::random_gaussian(6, 5, 0.0, 1.0, &mut rng));
        let out = master.run_blockmap(WorkerOp::RightMul(Arc::clone(&v)), &x).unwrap();
        assert_eq!(out.results_used, 3); // threshold K
        let (blocks, _) = split_rows(&x, 3);
        for (d, b) in out.blocks.iter().zip(&blocks) {
            assert!(d.rel_error(&matmul(b, &v)) < 1e-2);
        }
    }

    #[test]
    fn uncoded_round_waits_for_everyone() {
        let mut cfg = base_cfg(SchemeKind::Uncoded);
        cfg.partitions = 12;
        let mut master = Master::from_config(cfg).unwrap();
        let mut rng = rng_from_seed(3);
        let x = Matrix::random_gaussian(24, 4, 0.0, 1.0, &mut rng);
        let out = master.run_blockmap(WorkerOp::Identity, &x).unwrap();
        assert_eq!(out.results_used, 12);
    }

    #[test]
    fn matdot_round_full_product() {
        let mut cfg = base_cfg(SchemeKind::MatDot);
        cfg.partitions = 3;
        let mut master = Master::from_config(cfg).unwrap();
        let mut rng = rng_from_seed(4);
        let a = Matrix::random_gaussian(8, 9, 0.0, 1.0, &mut rng);
        let b = Matrix::random_gaussian(9, 7, 0.0, 1.0, &mut rng);
        let out = master.run_matmul(&a, &b).unwrap();
        assert_eq!(out.results_used, 5); // 2K−1
        assert_eq!(out.blocks.len(), 1);
        assert!(out.blocks[0].rel_error(&matmul(&a, &b)) < 1e-2);
    }

    #[test]
    fn blockmap_on_matdot_config_is_an_error() {
        let mut master = Master::from_config(base_cfg(SchemeKind::MatDot)).unwrap();
        let x = Matrix::ones(6, 4);
        assert!(master.run_blockmap(WorkerOp::Identity, &x).is_err());
    }

    #[test]
    fn mds_rejects_gram_tasks() {
        let mut master = Master::from_config(base_cfg(SchemeKind::Mds)).unwrap();
        let x = Matrix::ones(6, 4);
        assert!(master.run_blockmap(WorkerOp::Gram, &x).is_err());
    }

    #[test]
    fn eavesdropper_sees_only_ciphertext_under_mea() {
        let tap = Arc::new(EavesdropLog::new());
        let cfg = base_cfg(SchemeKind::Spacdc);
        let mut master = MasterBuilder::new(cfg).eavesdropper(Arc::clone(&tap)).build().unwrap();
        let mut rng = rng_from_seed(5);
        let x = Matrix::random_gaussian(24, 8, 0.0, 1.0, &mut rng);
        master.run_blockmap(WorkerOp::Identity, &x).unwrap();
        assert!(tap.count() > 0);
        // Reconstruct what the shares would be and check decorrelation.
        let params = CodeParams::new(12, 3, 2);
        let scheme = crate::coding::Spacdc::new(params);
        let enc = scheme.encode(&x, 1, &mut rng_from_seed(999)).unwrap();
        let corr = tap.downlink_correlation(&enc.shares);
        assert!(corr < 0.2, "wire payloads correlate with shares: {corr}");
    }

    #[test]
    fn plain_transport_leaks_to_eavesdropper() {
        let tap = Arc::new(EavesdropLog::new());
        let mut cfg = base_cfg(SchemeKind::Bacc);
        cfg.transport = TransportSecurity::Plain;
        cfg.seed = 77;
        let mut master = MasterBuilder::new(cfg).eavesdropper(Arc::clone(&tap)).build().unwrap();
        let mut rng = rng_from_seed(6);
        let x = Matrix::random_gaussian(24, 8, 0.0, 1.0, &mut rng);
        master.run_blockmap(WorkerOp::Identity, &x).unwrap();
        // BACC encode is deterministic → the true shares are exactly
        // reproducible, and the plaintext wire bytes must match them.
        let scheme = crate::coding::Bacc::new(CodeParams::new(12, 3, 0));
        let enc = scheme.encode(&x, 1, &mut rng_from_seed(0)).unwrap();
        let corr = tap.downlink_correlation(&enc.shares);
        assert!(corr > 0.5, "plaintext transport should leak: {corr}");
    }

    #[test]
    fn successive_rounds_reuse_pool() {
        let mut master = Master::from_config(base_cfg(SchemeKind::Spacdc)).unwrap();
        let mut rng = rng_from_seed(7);
        let x = Matrix::random_gaussian(12, 4, 0.0, 1.0, &mut rng);
        for _ in 0..3 {
            let out = master.run_blockmap(WorkerOp::Identity, &x).unwrap();
            assert_eq!(out.blocks.len(), 3);
        }
        // Late results from earlier rounds may or may not have landed,
        // but the master must still be consistent.
        assert!(master.metrics().get(names::TASKS_DISPATCHED) >= 36);
    }
}
