//! The master: drives encoded rounds end-to-end (encode → seal →
//! dispatch → collect → decrypt → decode) and owns all accounting.
//!
//! One pipeline serves every scheme and task shape: [`Master::run`]
//! executes a typed [`CodedTask`] synchronously, and the split-phase
//! [`Master::submit`] / [`Master::wait`] pair keeps several rounds in
//! flight against the worker pool at once — encode/seal/dispatch of
//! round r+1 overlaps the workers' compute of round r (see the
//! `pipelining` bench).

use super::messages::{ResultMsg, WirePayload, WorkOrder};
use super::pool::WorkerPool;
use crate::coding::{make_scheme, CodeParams, CodedTask, DecodeCtx, Scheme, Threshold};
use crate::config::{SystemConfig, TransportSecurity};
use crate::ecc::{sim_curve, KeyPair, MaskMode, MeaEcc};
use crate::field::Fp61;
use crate::matrix::Matrix;
use crate::metrics::{names, MetricsRegistry};
use crate::rng::{derive_seed, rng_from_seed, Rng};
use crate::runtime::Executor;
use crate::sim::{CollusionPool, DelayModel, EavesdropLog};
use std::collections::HashMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Result of one coded round.
#[derive(Debug)]
pub struct RoundOutcome {
    /// Decoded results: per-block `Yᵢ ≈ f(Xᵢ)` for block-map rounds, or
    /// a single full product for pair-product rounds.
    pub blocks: Vec<Matrix>,
    /// Wall-clock for the whole round (submit → decode done).
    pub wall: Duration,
    /// How many worker results the decoder consumed.
    pub results_used: usize,
}

/// A round in flight: returned by [`Master::submit`], consumed by
/// [`Master::wait`] (or released by [`Master::abandon`]). Deliberately
/// neither `Clone` nor constructible outside this module, so every
/// submitted round is waited on at most once.
///
/// Dropping a handle without waiting leaves the round's result buffer
/// allocated until the master is dropped — abandon rounds you will not
/// wait on.
#[derive(Debug)]
pub struct RoundHandle {
    round: u64,
}

impl RoundHandle {
    /// The monotone round id this handle tracks.
    pub fn round_id(&self) -> u64 {
        self.round
    }
}

/// Book-keeping for a submitted-but-undecoded round.
struct InflightRound {
    ctx: DecodeCtx,
    results: Vec<(usize, Matrix)>,
    threshold: Threshold,
    wait_for: usize,
    dispatched: usize,
    started: Instant,
}

/// Builder for [`Master`].
pub struct MasterBuilder {
    cfg: SystemConfig,
    executor: Option<Executor>,
    eavesdropper: Option<Arc<EavesdropLog>>,
    collusion: Option<Arc<CollusionPool>>,
    metrics: Option<Arc<MetricsRegistry>>,
}

impl MasterBuilder {
    /// Start from a config.
    pub fn new(cfg: SystemConfig) -> Self {
        Self { cfg, executor: None, eavesdropper: None, collusion: None, metrics: None }
    }

    /// Attach an executor (default: native with fresh metrics).
    pub fn executor(mut self, e: Executor) -> Self {
        self.executor = Some(e);
        self
    }

    /// Attach an eavesdropper tap.
    pub fn eavesdropper(mut self, tap: Arc<EavesdropLog>) -> Self {
        self.eavesdropper = Some(tap);
        self
    }

    /// Attach a collusion pool (its members leak their shares).
    pub fn collusion(mut self, pool: Arc<CollusionPool>) -> Self {
        self.collusion = Some(pool);
        self
    }

    /// Share a metrics registry.
    pub fn metrics(mut self, m: Arc<MetricsRegistry>) -> Self {
        self.metrics = Some(m);
        self
    }

    /// Spawn the worker pool and build the master.
    pub fn build(self) -> anyhow::Result<Master> {
        self.cfg.validate().map_err(|e| anyhow::anyhow!(e.to_string()))?;
        let metrics = self.metrics.unwrap_or_else(|| Arc::new(MetricsRegistry::new()));
        let executor =
            self.executor.unwrap_or_else(|| Executor::native(Arc::clone(&metrics)));
        let curve = sim_curve();
        let mut rng = rng_from_seed(derive_seed(self.cfg.seed, 0x3A57E2));
        let keys = KeyPair::generate(&curve, &mut rng);
        let pool = WorkerPool::spawn(
            self.cfg.workers,
            keys.public(),
            executor,
            self.collusion.clone(),
            self.cfg.seed,
        );
        let params =
            CodeParams::new(self.cfg.workers, self.cfg.partitions, self.cfg.colluders);
        // Total over every SchemeKind — MatDot included; no Option field,
        // no second code path.
        let scheme = make_scheme(self.cfg.scheme, params);
        let delays = DelayModel::new(
            self.cfg.workers,
            self.cfg.stragglers,
            self.cfg.delay,
            self.cfg.seed,
        );
        Ok(Master {
            cfg: self.cfg,
            scheme,
            pool,
            keys,
            mea: MeaEcc::new(curve, MaskMode::Keystream),
            metrics,
            eavesdropper: self.eavesdropper,
            delays,
            round: 0,
            rng,
            inflight: HashMap::new(),
            outstanding: HashMap::new(),
        })
    }
}

/// The master node.
pub struct Master {
    cfg: SystemConfig,
    scheme: Box<dyn Scheme>,
    pool: WorkerPool,
    keys: KeyPair<Fp61>,
    mea: MeaEcc<Fp61>,
    metrics: Arc<MetricsRegistry>,
    eavesdropper: Option<Arc<EavesdropLog>>,
    delays: DelayModel,
    round: u64,
    rng: Rng,
    /// Rounds submitted but not yet waited on, with buffered results.
    inflight: HashMap<u64, InflightRound>,
    /// Completed round → results still in flight (late-arrival accounting).
    outstanding: HashMap<u64, usize>,
}

impl Master {
    /// Convenience: build with defaults from a config.
    pub fn from_config(cfg: SystemConfig) -> anyhow::Result<Self> {
        MasterBuilder::new(cfg).build()
    }

    /// The metrics registry.
    pub fn metrics(&self) -> &Arc<MetricsRegistry> {
        &self.metrics
    }

    /// The active config.
    pub fn config(&self) -> &SystemConfig {
        &self.cfg
    }

    /// The configured coding scheme.
    pub fn scheme(&self) -> &dyn Scheme {
        &*self.scheme
    }

    /// The straggler set chosen for this scenario.
    pub fn straggler_set(&self) -> Vec<usize> {
        self.delays.straggler_set()
    }

    /// Run one coded round synchronously: encode `task` with the
    /// configured scheme, dispatch, collect, decode.
    pub fn run(&mut self, task: CodedTask) -> anyhow::Result<RoundOutcome> {
        let handle = self.submit(task)?;
        self.wait(handle)
    }

    /// Phase 1+2 of a round: encode `task`, seal the per-worker payloads,
    /// and dispatch the work orders. Returns immediately with a
    /// [`RoundHandle`]; several rounds may be in flight at once, and
    /// [`Master::wait`] routes interleaved results to the right round.
    pub fn submit(&mut self, task: CodedTask) -> anyhow::Result<RoundHandle> {
        if !self.scheme.supports(&task) {
            anyhow::bail!(
                "{} does not support {} tasks",
                self.scheme.kind().name(),
                task.name()
            );
        }
        // Absorb results that landed since the last call (late arrivals
        // of completed rounds, early arrivals of in-flight ones).
        self.drain_pending();
        self.round += 1;
        let round = self.round;
        let started = Instant::now();

        // Encode (+T masks) — §V-B "data process".
        let job = {
            let _t = self.metrics.time_phase("phase.encode");
            self.scheme.encode(&task, &mut self.rng)?
        };
        let threshold = self.scheme.threshold(&task);
        let wait_for = self.wait_count(threshold);
        let dispatched = job.payloads.len();

        // Seal and dispatch every worker's operand payloads.
        {
            let metrics = Arc::clone(&self.metrics);
            let _t = metrics.time_phase("phase.dispatch");
            for (w, operands) in job.payloads.iter().enumerate() {
                let payloads: Vec<WirePayload> =
                    operands.iter().map(|m| self.seal_for(w, m)).collect();
                for p in &payloads {
                    self.capture(w, true, p);
                    self.metrics.add(names::SYMBOLS_TO_WORKERS, p.symbols() as u64);
                }
                self.metrics.inc(names::TASKS_DISPATCHED);
                self.pool.dispatch(WorkOrder {
                    round,
                    worker: w,
                    op: job.op.clone(),
                    payloads,
                    delay: self.delays.service_delay(w, round),
                });
            }
        }

        self.inflight.insert(
            round,
            InflightRound {
                ctx: job.ctx,
                results: Vec::new(),
                threshold,
                wait_for,
                dispatched,
                started,
            },
        );
        Ok(RoundHandle { round })
    }

    /// Phase 3 of a round: collect results until the scheme's wait policy
    /// is satisfied, then decode. Results belonging to *other* in-flight
    /// rounds are buffered for their own `wait`, so rounds may be waited
    /// on in any order.
    pub fn wait(&mut self, handle: RoundHandle) -> anyhow::Result<RoundOutcome> {
        let round = handle.round;
        anyhow::ensure!(
            self.inflight.contains_key(&round),
            "round {round} is not in flight"
        );
        {
            let metrics = Arc::clone(&self.metrics);
            let _t = metrics.time_phase("phase.wait");
            // One absolute deadline for the whole collection: traffic
            // from other in-flight rounds must not keep re-arming it.
            let deadline = Instant::now() + Duration::from_secs(60);
            while self.inflight[&round].results.len() < self.inflight[&round].wait_for {
                let remaining = deadline.saturating_duration_since(Instant::now());
                let msg: ResultMsg = match self.pool.results().recv_timeout(remaining) {
                    Ok(msg) => msg,
                    Err(_) => {
                        // Abandon the round: drop its buffer so later
                        // arrivals are counted late instead of being
                        // unsealed and hoarded forever.
                        self.release(round);
                        anyhow::bail!(
                            "timed out waiting for worker results (round {round})"
                        );
                    }
                };
                self.route(msg);
            }
        }
        let done = self.inflight.remove(&round).expect("checked in flight above");
        // Anything not yet received is in flight → counted late when it
        // lands during a later submit/wait.
        self.outstanding.insert(round, done.dispatched - done.results.len());
        // An exact-threshold decode consumes exactly its threshold;
        // results buffered beyond it (possible when other rounds were
        // waited on first) are wasted work, same as post-decode arrivals.
        let used = match done.threshold {
            Threshold::Exact(k) => k.min(done.results.len()),
            Threshold::Flexible { .. } => done.results.len(),
        };
        let extras = done.results.len() - used;
        self.metrics.add(names::RESULTS_USED, used as u64);
        if extras > 0 {
            self.metrics.add(names::RESULTS_LATE, extras as u64);
        }
        let decoded = {
            let _t = self.metrics.time_phase("phase.decode");
            self.scheme.decode(&done.ctx, &done.results)?
        };
        Ok(RoundOutcome { blocks: decoded, wall: done.started.elapsed(), results_used: used })
    }

    /// Give up on a submitted round without decoding it: its buffered
    /// results are counted as wasted work and its entry is dropped, so
    /// later arrivals go through the late-result accounting instead of
    /// being unsealed and buffered forever. Use this for rounds that
    /// will never be waited on (e.g. when a batch is cancelled part-way
    /// through submission).
    pub fn abandon(&mut self, handle: RoundHandle) {
        self.release(handle.round);
    }

    /// Drop an in-flight round's book-keeping, settling its accounting.
    fn release(&mut self, round: u64) {
        if let Some(dead) = self.inflight.remove(&round) {
            self.outstanding.insert(round, dead.dispatched - dead.results.len());
            self.metrics.add(names::RESULTS_LATE, dead.results.len() as u64);
        }
    }

    /// How many results to wait for, given the scheme's threshold.
    fn wait_count(&self, threshold: Threshold) -> usize {
        match threshold {
            Threshold::Exact(k) => k,
            // Flexible: take what the non-stragglers produce (paper's
            // experimental policy — decode fires when the fast workers
            // are in, without waiting out the stragglers).
            Threshold::Flexible { min } => (self.cfg.workers - self.cfg.stragglers).max(min),
        }
    }

    /// Deliver one worker result: buffered under its in-flight round, or
    /// counted late if that round already decoded. (RESULTS_USED /
    /// RESULTS_LATE for buffered results are settled at decode time in
    /// [`Master::wait`], once it is known how many the decoder consumed.)
    fn route(&mut self, msg: ResultMsg) {
        if !self.inflight.contains_key(&msg.round) {
            self.note_stale(msg.round);
            return;
        }
        self.capture(msg.worker, false, &msg.payload);
        self.metrics.add(names::SYMBOLS_TO_MASTER, msg.payload.symbols() as u64);
        let m = self.unseal(&msg.payload);
        self.inflight
            .get_mut(&msg.round)
            .expect("checked above")
            .results
            .push((msg.worker, m));
    }

    /// Seal (or pass through) a share for worker `w`.
    fn seal_for(&mut self, w: usize, m: &Matrix) -> WirePayload {
        match self.cfg.transport {
            TransportSecurity::Plain => WirePayload::Plain(m.clone()),
            TransportSecurity::MeaEcc => WirePayload::Sealed(self.mea.encrypt(
                m,
                &self.pool.worker_pks()[w],
                &mut self.rng,
            )),
        }
    }

    /// Unseal a worker result.
    fn unseal(&self, p: &WirePayload) -> Matrix {
        match p {
            WirePayload::Plain(m) => m.clone(),
            WirePayload::Sealed(s) => self.mea.decrypt(s, &self.keys),
        }
    }

    /// Record an eavesdropped wire payload.
    fn capture(&self, worker: usize, downlink: bool, p: &WirePayload) {
        if let Some(tap) = &self.eavesdropper {
            tap.capture(worker, downlink, p.wire_view());
        }
    }

    /// Drain already-arrived results without blocking, routing each to
    /// its in-flight round or the late-arrival accounting.
    fn drain_pending(&mut self) {
        while let Ok(msg) = self.pool.results().try_recv() {
            self.route(msg);
        }
    }

    fn note_stale(&mut self, round: u64) {
        self.metrics.inc(names::RESULTS_LATE);
        if let Some(left) = self.outstanding.get_mut(&round) {
            *left = left.saturating_sub(1);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::BlockCode;
    use crate::config::SchemeKind;
    use crate::matrix::{matmul, split_rows};
    use crate::runtime::WorkerOp;

    fn base_cfg(scheme: SchemeKind) -> SystemConfig {
        let mut cfg = SystemConfig::default();
        cfg.workers = 12;
        cfg.partitions = 3;
        cfg.colluders = 2;
        cfg.stragglers = 2;
        cfg.scheme = scheme;
        cfg.delay.base_service_s = 0.0; // fast tests
        cfg
    }

    #[test]
    fn spacdc_round_end_to_end_sealed() {
        let mut master = Master::from_config(base_cfg(SchemeKind::Spacdc)).unwrap();
        let mut rng = rng_from_seed(1);
        let x = Matrix::random_gaussian(24, 8, 0.0, 1.0, &mut rng);
        let v = Arc::new(Matrix::random_gaussian(8, 4, 0.0, 1.0, &mut rng));
        let out = master
            .run(CodedTask::block_map(WorkerOp::RightMul(Arc::clone(&v)), x.clone()))
            .unwrap();
        assert_eq!(out.blocks.len(), 3);
        assert_eq!(out.results_used, 10); // N − S
        let (blocks, _) = split_rows(&x, 3);
        for (d, b) in out.blocks.iter().zip(&blocks) {
            let err = d.rel_error(&matmul(b, &v));
            // Approximate decode at N=12, S=2, with privacy masks: the
            // bound here is coarse; accuracy-vs-returns is characterized
            // precisely in the coding-layer tests.
            assert!(err < 0.5, "err={err}");
        }
        // Transport accounting is live.
        assert!(master.metrics().get(names::SYMBOLS_TO_WORKERS) > 0);
        assert!(master.metrics().get(names::SYMBOLS_TO_MASTER) > 0);
    }

    #[test]
    fn mds_round_exact_decode() {
        let mut cfg = base_cfg(SchemeKind::Mds);
        cfg.transport = TransportSecurity::Plain;
        let mut master = Master::from_config(cfg).unwrap();
        let mut rng = rng_from_seed(2);
        let x = Matrix::random_gaussian(24, 6, 0.0, 1.0, &mut rng);
        let v = Arc::new(Matrix::random_gaussian(6, 5, 0.0, 1.0, &mut rng));
        let out = master
            .run(CodedTask::block_map(WorkerOp::RightMul(Arc::clone(&v)), x.clone()))
            .unwrap();
        assert_eq!(out.results_used, 3); // threshold K
        let (blocks, _) = split_rows(&x, 3);
        for (d, b) in out.blocks.iter().zip(&blocks) {
            assert!(d.rel_error(&matmul(b, &v)) < 1e-2);
        }
    }

    #[test]
    fn uncoded_round_waits_for_everyone() {
        let mut cfg = base_cfg(SchemeKind::Uncoded);
        cfg.partitions = 12;
        let mut master = Master::from_config(cfg).unwrap();
        let mut rng = rng_from_seed(3);
        let x = Matrix::random_gaussian(24, 4, 0.0, 1.0, &mut rng);
        let out = master.run(CodedTask::block_map(WorkerOp::Identity, x)).unwrap();
        assert_eq!(out.results_used, 12);
    }

    #[test]
    fn matdot_round_full_product() {
        let mut cfg = base_cfg(SchemeKind::MatDot);
        cfg.partitions = 3;
        let mut master = Master::from_config(cfg).unwrap();
        let mut rng = rng_from_seed(4);
        let a = Matrix::random_gaussian(8, 9, 0.0, 1.0, &mut rng);
        let b = Matrix::random_gaussian(9, 7, 0.0, 1.0, &mut rng);
        let out = master.run(CodedTask::pair_product(a.clone(), b.clone())).unwrap();
        assert_eq!(out.results_used, 5); // 2K−1
        assert_eq!(out.blocks.len(), 1);
        assert!(out.blocks[0].rel_error(&matmul(&a, &b)) < 1e-2);
    }

    #[test]
    fn pair_product_through_a_row_partition_scheme() {
        // The unified surface: the same task MatDot serves natively runs
        // on SPACDC by encode(A) + broadcast right-multiply.
        let mut master = Master::from_config(base_cfg(SchemeKind::Spacdc)).unwrap();
        let mut rng = rng_from_seed(40);
        let a = Matrix::random_gaussian(24, 8, 0.0, 1.0, &mut rng);
        let b = Matrix::random_gaussian(8, 5, 0.0, 1.0, &mut rng);
        let out = master.run(CodedTask::pair_product(a.clone(), b.clone())).unwrap();
        assert_eq!(out.blocks.len(), 1);
        assert_eq!(out.blocks[0].shape(), (24, 5));
        assert!(out.blocks[0].rel_error(&matmul(&a, &b)) < 0.5);
    }

    #[test]
    fn blockmap_on_matdot_config_is_an_error() {
        let mut master = Master::from_config(base_cfg(SchemeKind::MatDot)).unwrap();
        let x = Matrix::ones(6, 4);
        assert!(master.run(CodedTask::block_map(WorkerOp::Identity, x)).is_err());
    }

    #[test]
    fn mds_rejects_gram_tasks() {
        let mut master = Master::from_config(base_cfg(SchemeKind::Mds)).unwrap();
        let x = Matrix::ones(6, 4);
        assert!(master.run(CodedTask::block_map(WorkerOp::Gram, x)).is_err());
    }

    #[test]
    fn submitted_rounds_interleave_without_bleed() {
        let mut master = Master::from_config(base_cfg(SchemeKind::Spacdc)).unwrap();
        let mut rng = rng_from_seed(41);
        let x1 = Matrix::random_gaussian(12, 4, 0.0, 1.0, &mut rng);
        let x2 = Matrix::random_gaussian(12, 4, 0.0, 1.0, &mut rng);
        let h1 = master.submit(CodedTask::block_map(WorkerOp::Identity, x1.clone())).unwrap();
        let h2 = master.submit(CodedTask::block_map(WorkerOp::Identity, x2.clone())).unwrap();
        assert_ne!(h1.round_id(), h2.round_id());
        // Wait in reverse submission order: round 1 results arriving
        // while we wait on round 2 must be buffered, not dropped.
        let out2 = master.wait(h2).unwrap();
        let out1 = master.wait(h1).unwrap();
        let (b1, _) = split_rows(&x1, 3);
        let (b2, _) = split_rows(&x2, 3);
        for ((d1, e1), (d2, e2)) in
            out1.blocks.iter().zip(&b1).zip(out2.blocks.iter().zip(&b2))
        {
            assert!(d1.rel_error(e1) < 0.5, "round 1 decode off: {}", d1.rel_error(e1));
            assert!(d2.rel_error(e2) < 0.5, "round 2 decode off: {}", d2.rel_error(e2));
        }
    }

    #[test]
    fn abandoned_rounds_settle_their_accounting() {
        let mut master = Master::from_config(base_cfg(SchemeKind::Spacdc)).unwrap();
        let x = Matrix::ones(12, 4);
        let h = master.submit(CodedTask::block_map(WorkerOp::Identity, x.clone())).unwrap();
        master.abandon(h);
        // The abandoned round's results now land through the stale path;
        // the next full round must still work and count them late.
        std::thread::sleep(std::time::Duration::from_millis(20));
        let out = master.run(CodedTask::block_map(WorkerOp::Identity, x)).unwrap();
        assert_eq!(out.blocks.len(), 3);
        assert!(master.metrics().get(names::RESULTS_LATE) > 0);
    }

    #[test]
    fn eavesdropper_sees_only_ciphertext_under_mea() {
        let tap = Arc::new(EavesdropLog::new());
        let cfg = base_cfg(SchemeKind::Spacdc);
        let mut master = MasterBuilder::new(cfg).eavesdropper(Arc::clone(&tap)).build().unwrap();
        let mut rng = rng_from_seed(5);
        let x = Matrix::random_gaussian(24, 8, 0.0, 1.0, &mut rng);
        master.run(CodedTask::block_map(WorkerOp::Identity, x.clone())).unwrap();
        assert!(tap.count() > 0);
        // Reconstruct what the shares would be and check decorrelation.
        let params = CodeParams::new(12, 3, 2);
        let scheme = crate::coding::Spacdc::new(params);
        let enc = scheme.encode_blocks(&x, 1, &mut rng_from_seed(999)).unwrap();
        let corr = tap.downlink_correlation(&enc.shares);
        assert!(corr < 0.2, "wire payloads correlate with shares: {corr}");
    }

    #[test]
    fn plain_transport_leaks_to_eavesdropper() {
        let tap = Arc::new(EavesdropLog::new());
        let mut cfg = base_cfg(SchemeKind::Bacc);
        cfg.transport = TransportSecurity::Plain;
        cfg.seed = 77;
        let mut master = MasterBuilder::new(cfg).eavesdropper(Arc::clone(&tap)).build().unwrap();
        let mut rng = rng_from_seed(6);
        let x = Matrix::random_gaussian(24, 8, 0.0, 1.0, &mut rng);
        master.run(CodedTask::block_map(WorkerOp::Identity, x.clone())).unwrap();
        // BACC encode is deterministic → the true shares are exactly
        // reproducible, and the plaintext wire bytes must match them.
        let scheme = crate::coding::Bacc::new(CodeParams::new(12, 3, 0));
        let enc = scheme.encode_blocks(&x, 1, &mut rng_from_seed(0)).unwrap();
        let corr = tap.downlink_correlation(&enc.shares);
        assert!(corr > 0.5, "plaintext transport should leak: {corr}");
    }

    #[test]
    fn successive_rounds_reuse_pool() {
        let mut master = Master::from_config(base_cfg(SchemeKind::Spacdc)).unwrap();
        let mut rng = rng_from_seed(7);
        let x = Matrix::random_gaussian(12, 4, 0.0, 1.0, &mut rng);
        for _ in 0..3 {
            let out = master.run(CodedTask::block_map(WorkerOp::Identity, x.clone())).unwrap();
            assert_eq!(out.blocks.len(), 3);
        }
        // Late results from earlier rounds may or may not have landed,
        // but the master must still be consistent.
        assert!(master.metrics().get(names::TASKS_DISPATCHED) >= 36);
    }
}
