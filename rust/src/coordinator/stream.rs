//! Windowed round streams — DESIGN.md §8.
//!
//! [`Master::submit`]/[`Master::wait`] already let rounds overlap;
//! [`Master::run_stream`] turns that into a policy: keep up to
//! `inflight` rounds in flight at once, waiting on the oldest round
//! (FIFO) whenever the window is full. The window hides the master's
//! per-round encode/seal/decode work behind the workers' compute — at
//! `inflight = 1` the stream degenerates to the synchronous
//! [`Master::run`] loop, and wider windows raise round throughput until
//! the slower of the master and the worker fabric saturates (the
//! `stream` scenario's CI gate pins the ratio).
//!
//! Since the session redesign (DESIGN.md §12), `run_stream` is a thin
//! single-tenant wrapper over the serving front end: one iterator-fed
//! [`Service`](super::Service) lane in compatibility mode. A one-lane
//! service with lane window = global cap = `inflight` emits exactly the
//! old submit/wait sequence, so the wrapper is bit-identical to the
//! pre-session implementation — the scenario digests pin that in CI.
//!
//! **Determinism across window widths.** For a fixed seed and task
//! list, every round's outcome — decoded bits, results used, degraded
//! flag — is identical at any `inflight`, on either transport, at any
//! thread-pool width. That holds because (a) tasks are submitted in
//! list order, so the master's per-round RNG draws never move; (b) each
//! worker serves its link FIFO, so round r's share is computed from the
//! same bytes whenever it is queued; (c) lifecycle events are booked at
//! submit time in round order, so the dispatch set for round r is a
//! function of r, not of how far ahead the submitter runs (graceful
//! relinks keep old incarnations draining — see
//! `transport::Tcp::relink`); and (d) speculative re-dispatch is keyed
//! on written-off shares, which are booked the same way. The scenario
//! digest pins all of this in CI across `inflight ∈ {1, 4, 16}`.

use super::master::{Master, RoundOutcome};
use crate::coding::CodedTask;
use crate::config::SystemConfig;
use std::time::Duration;

/// Streaming knobs (config keys `inflight` / `speculate`, CLI
/// `--inflight` / `--speculate`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StreamConfig {
    /// Maximum rounds in flight at once (≥ 1; 1 = synchronous).
    pub inflight: usize,
    /// Re-dispatch outstanding shares to other workers (lost shares
    /// immediately, live-but-slow shares at the deadline checkpoint).
    pub speculate: bool,
}

impl StreamConfig {
    /// The stream knobs a config asks for.
    pub fn from_config(cfg: &SystemConfig) -> Self {
        Self { inflight: cfg.inflight.max(1), speculate: cfg.speculate }
    }
}

/// One round of a stream, in task-list order.
#[derive(Debug)]
pub struct StreamRound {
    /// Position in the submitted task list (0-based).
    pub index: usize,
    /// The master's round id (0 when the submit itself failed before an
    /// id was exposed).
    pub round: u64,
    /// The round's fate: a decoded outcome, or the typed error `wait`
    /// (or `submit`) produced. One round failing never aborts the
    /// stream — later rounds keep flowing.
    pub outcome: anyhow::Result<RoundOutcome>,
}

/// What a whole stream did.
#[derive(Debug)]
pub struct StreamOutcome {
    /// Per-round results, ordered by task-list position.
    pub rounds: Vec<StreamRound>,
    /// Wall-clock for the whole stream (first submit → last wait).
    pub wall: Duration,
    /// Round throughput over the stream (rounds / `wall`).
    pub rounds_per_s: f64,
    /// Speculative work orders sent during the stream.
    pub redispatched: u64,
    /// Written-off shares recovered by speculation during the stream.
    pub recovered: u64,
    /// Duplicate share copies discarded (speculation losers) during the
    /// stream.
    pub wasted: u64,
    /// Mean window occupancy (rounds in flight), sampled at every
    /// submit and wait — how full the window actually ran.
    pub occupancy_mean: f64,
    /// Peak window occupancy (≤ `inflight`).
    pub occupancy_max: usize,
}

impl StreamOutcome {
    /// How many rounds decoded successfully.
    pub fn decoded(&self) -> usize {
        self.rounds.iter().filter(|r| r.outcome.is_ok()).count()
    }
}

impl Master {
    /// Drive `tasks` through the coordinator as a windowed stream: up to
    /// `sc.inflight` rounds in flight, FIFO waits, speculation per
    /// `sc.speculate` (restored to the config's setting afterwards).
    /// Individual round failures are captured per round, not returned —
    /// the stream always runs to the end of the task list.
    ///
    /// This is a convenience wrapper over the session front end
    /// (DESIGN.md §12): one iterator-fed single-tenant
    /// [`Service`](super::Service) lane in compatibility mode (no
    /// tenant seed, the config deadline), bit-identical to the
    /// pre-session stream at every window width.
    pub fn run_stream(
        &mut self,
        tasks: Vec<CodedTask>,
        sc: StreamConfig,
    ) -> anyhow::Result<StreamOutcome> {
        anyhow::ensure!(sc.inflight >= 1, "stream window must be ≥ 1, got {}", sc.inflight);
        let mut svc = self.service(super::ServiceConfig {
            global_inflight: sc.inflight,
            speculate: sc.speculate,
        });
        let sid = svc.open_iter(
            "stream",
            super::SessionOptions { inflight: sc.inflight, ..Default::default() },
            tasks.into_iter(),
        );
        let mut out = svc.run();
        let lane = &out.tenants[sid];
        let rounds: Vec<StreamRound> = out.rounds[sid]
            .drain(..)
            .map(|r| StreamRound { index: r.index, round: r.round, outcome: r.outcome })
            .collect();
        Ok(StreamOutcome {
            rounds,
            wall: out.wall,
            rounds_per_s: out.rounds_per_s,
            redispatched: out.redispatched,
            recovered: out.recovered,
            wasted: out.wasted,
            occupancy_mean: lane.occupancy_mean,
            occupancy_max: lane.occupancy_max,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SchemeKind;
    use crate::matrix::{matmul, split_rows, Matrix};
    use crate::rng::rng_from_seed;
    use crate::runtime::WorkerOp;
    use std::sync::Arc;

    fn cfg() -> SystemConfig {
        let mut cfg = SystemConfig::default();
        cfg.workers = 10;
        cfg.partitions = 3;
        cfg.colluders = 2;
        cfg.stragglers = 2;
        cfg.scheme = SchemeKind::Spacdc;
        cfg.delay.base_service_s = 0.0;
        cfg
    }

    fn tasks(n: usize, seed: u64) -> (Vec<CodedTask>, Vec<Matrix>, Arc<Matrix>) {
        let mut rng = rng_from_seed(seed);
        let v = Arc::new(Matrix::random_gaussian(6, 4, 0.0, 1.0, &mut rng));
        let xs: Vec<Matrix> =
            (0..n).map(|_| Matrix::random_gaussian(12, 6, 0.0, 1.0, &mut rng)).collect();
        let ts = xs
            .iter()
            .map(|x| CodedTask::block_map(WorkerOp::RightMul(Arc::clone(&v)), x.clone()))
            .collect();
        (ts, xs, v)
    }

    #[test]
    fn stream_decodes_every_round_in_task_order() {
        let mut master = Master::from_config(cfg()).unwrap();
        let (ts, xs, v) = tasks(6, 11);
        let out = master
            .run_stream(ts, StreamConfig { inflight: 3, speculate: false })
            .unwrap();
        assert_eq!(out.rounds.len(), 6);
        assert_eq!(out.decoded(), 6);
        for (i, r) in out.rounds.iter().enumerate() {
            assert_eq!(r.index, i);
            assert_eq!(r.round, i as u64 + 1, "FIFO submits number the rounds in order");
            let decoded = r.outcome.as_ref().unwrap();
            let (blocks, _) = split_rows(&xs[i], 3);
            for (d, b) in decoded.blocks.iter().zip(&blocks) {
                assert!(d.rel_error(&matmul(b, &v)) < 0.5);
            }
        }
        assert!(out.rounds_per_s > 0.0);
        assert_eq!(out.redispatched, 0, "no speculation requested");
        assert!(
            (1..=3).contains(&out.occupancy_max),
            "window occupancy is surfaced and bounded by inflight: {}",
            out.occupancy_max
        );
    }

    #[test]
    fn window_of_one_matches_the_synchronous_loop_bitwise() {
        let (ts, _, _) = tasks(4, 22);
        let mut synchronous = Master::from_config(cfg()).unwrap();
        let mut blocks_sync = Vec::new();
        for t in ts {
            blocks_sync.push(synchronous.run(t).unwrap().blocks);
        }
        let (ts, _, _) = tasks(4, 22);
        let mut streamed = Master::from_config(cfg()).unwrap();
        let out = streamed
            .run_stream(ts, StreamConfig { inflight: 1, speculate: false })
            .unwrap();
        for (sync, stream) in blocks_sync.iter().zip(&out.rounds) {
            let stream = &stream.outcome.as_ref().unwrap().blocks;
            assert_eq!(sync.len(), stream.len());
            for (a, b) in sync.iter().zip(stream) {
                assert_eq!(a, b, "inflight=1 must be bit-identical to run()");
            }
        }
    }

    #[test]
    fn oversized_window_is_capped_by_the_task_list() {
        let mut master = Master::from_config(cfg()).unwrap();
        let (ts, _, _) = tasks(3, 33);
        let out = master
            .run_stream(ts, StreamConfig { inflight: 16, speculate: false })
            .unwrap();
        assert_eq!(out.decoded(), 3, "window wider than the stream is fine");
    }

    #[test]
    fn stream_config_comes_from_the_system_config() {
        let mut c = cfg();
        c.inflight = 8;
        c.speculate = true;
        assert_eq!(
            StreamConfig::from_config(&c),
            StreamConfig { inflight: 8, speculate: true }
        );
    }
}
