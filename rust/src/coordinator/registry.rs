//! The in-flight round registry: the rendezvous between the submit
//! path, the background collector thread, and round handles.
//!
//! `Master::submit` registers a round before dispatching its orders; the
//! collector thread [`deliver`](RoundRegistry::deliver)s every decoded
//! result to its round (or the late-arrival accounting); `Master::wait`
//! blocks on the condvar until the round's wait policy is satisfied or
//! its deadline passes. Because delivery happens on the collector
//! thread, waiting on one round never stalls result intake for the
//! others, and a dropped [`RoundHandle`](super::RoundHandle) can settle
//! its round's accounting from wherever it is dropped.
//!
//! **Partial-failure accounting.** Every round tracks which dispatched
//! workers still owe it a result (`pending`). When the master learns a
//! worker is gone — a scheduled mid-round crash, a corrupted result
//! frame, or a dead link — it calls [`note_lost`](RoundRegistry::note_lost)
//! / [`note_worker_down`](RoundRegistry::note_worker_down), and the
//! round re-evaluates what can still arrive:
//!
//! * still enough for the current policy → nothing changes;
//! * short of the policy but at least the scheme's hard minimum → the
//!   wait target is *degraded* to "decode from what can still arrive"
//!   (flexible-threshold schemes — the paper's headline property);
//! * below the hard minimum → the round is *hopeless* and the waiter is
//!   woken immediately with a typed error, instead of burning its whole
//!   deadline on results that can never come.
//!
//! A result from a worker the master wrote off can still arrive (the
//! master is deliberately pessimistic); it is buffered normally — the
//! round just finishes earlier than feared.
//!
//! **Speculative re-dispatch** (DESIGN.md §8). A written-off share is
//! not forgotten: it moves to the round's `lost` set, and the master —
//! when speculation is on — re-sends that share's work order to another
//! live worker ([`respeculate`](RoundRegistry::respeculate): the share
//! returns to `pending`, the round's wait target is restored toward the
//! original policy, and a `hopeless` verdict is rescinded when the
//! threshold becomes reachable again). Near the deadline the master may
//! also duplicate still-pending shares onto idle workers
//! ([`respeculate_dup`](RoundRegistry::respeculate_dup)). Either way the
//! rule is *first result wins, per share id*: a share already buffered
//! rejects later copies deterministically (`spec.wasted`), and because
//! every copy of a share carries bit-identical payload math, the decode
//! input never depends on which copy won.

use crate::coding::{DecodeCtx, Threshold};
use crate::matrix::Matrix;
use crate::metrics::{names, MetricsRegistry};
use std::collections::HashMap;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

/// Book-keeping for a submitted-but-undecoded round.
#[derive(Debug)]
pub(crate) struct InflightRound {
    /// Everything the decoder needs, produced at encode time.
    pub ctx: DecodeCtx,
    /// The scheme's recovery-threshold semantics for this round.
    pub threshold: Threshold,
    /// Decoded (share, result) pairs buffered so far — capped at
    /// `wait_for`: once the policy is satisfied the buffer is frozen, so
    /// the decode input set is exactly the first `wait_for` arrivals
    /// (deterministic `results_used`, same as the old blocking recv loop).
    /// At most one entry per share id: duplicate copies (speculation
    /// losers) are discarded on arrival.
    pub results: Vec<(usize, Matrix)>,
    /// How many results the wait policy needs right now (lowered by
    /// mid-round losses, restored by speculative recovery — see module
    /// docs).
    pub wait_for: usize,
    /// The wait count the policy originally asked for at finalize time;
    /// `wait_for` never exceeds it.
    pub policy_wait: usize,
    /// The scheme's hard floor: `Exact(k)` needs exactly `k`,
    /// `Flexible { min }` can degrade down to `min` but no further.
    pub min_required: usize,
    /// How many orders went out for this round (speculative re-sends
    /// included) — the denominator for late-arrival accounting.
    pub dispatched: usize,
    /// Share ids still expected to produce a result (original owner or a
    /// speculative executor).
    pub pending: Vec<usize>,
    /// Share ids written off (owner crashed, frame corrupted): nothing
    /// is expected from them, but they are eligible for speculative
    /// re-dispatch and a zombie delivery is still welcome.
    pub lost: Vec<usize>,
    /// Lost shares re-dispatched speculatively and not yet settled —
    /// their first arrival counts as recovered work.
    pub spec_pending: Vec<usize>,
    /// Still-pending shares duplicated onto an idle worker near the
    /// deadline — the losing copy counts as wasted speculation.
    pub spec_dup: Vec<usize>,
    /// Was `wait_for` lowered below the original policy?
    pub degraded: bool,
    /// Set when fewer than `min_required` results can still arrive:
    /// `(possible, need)`.
    pub hopeless: Option<(usize, usize)>,
    /// Results that arrived while in flight but after the buffer froze
    /// (already counted as wasted work).
    pub spilled: usize,
    /// Per-buffered-result (symbols, frame bytes), index-aligned with
    /// `results`. Fed to `comm.symbols_to_master` / `comm.bytes_rx` at
    /// decode time, so those counters reflect exactly the decode inputs
    /// — deterministic, like the old blocking recv loop.
    pub sizes: Vec<(u64, u64)>,
    /// Submit time (for the round's wall-clock).
    pub started: Instant,
}

impl InflightRound {
    /// Total (symbols, frame bytes) of the buffered results.
    pub fn received_totals(&self) -> (u64, u64) {
        self.sizes.iter().fold((0, 0), |(s, b), (ds, db)| (s + ds, b + db))
    }

    /// Results that can still reach the buffer: already there + pending.
    fn possible(&self) -> usize {
        self.results.len() + self.pending.len()
    }
}

/// Outcome of a non-abandoning [`wait_soft`](RoundRegistry::wait_soft)
/// — the speculation checkpoint's view of a round.
#[derive(Debug)]
pub(crate) enum SoftWait {
    /// The round completed (retired exactly as `wait_done` would).
    Done(InflightRound),
    /// The checkpoint passed (or the round is hopeless) with shares
    /// still outstanding; nothing was abandoned.
    Blocked {
        /// Shares still expected when the checkpoint fired.
        pending: Vec<usize>,
        /// The round's threshold is currently unreachable.
        hopeless: bool,
    },
    /// The round is not in flight.
    Gone,
}

/// Why a wait did not produce a round.
#[derive(Debug, PartialEq, Eq)]
pub(crate) enum WaitError {
    /// The round is not in flight (never submitted, already waited on,
    /// or abandoned).
    Unknown(u64),
    /// The deadline passed first; the round has been abandoned. Enough
    /// workers were still live for the policy — they were just slow.
    TimedOut {
        /// The round that timed out.
        round: u64,
        /// Results buffered when the deadline hit.
        got: usize,
        /// Results the wait policy wanted.
        need: usize,
    },
    /// Too many workers are down for the wait policy to ever be
    /// satisfied; the round has been abandoned without waiting out the
    /// deadline.
    Hopeless {
        /// The doomed round.
        round: u64,
        /// Results that could still have arrived.
        possible: usize,
        /// The scheme's hard minimum.
        need: usize,
    },
}

#[derive(Default)]
struct State {
    rounds: HashMap<u64, InflightRound>,
    /// Completed/abandoned round → results still expected from workers
    /// (late-arrival accounting).
    outstanding: HashMap<u64, usize>,
}

/// Shared registry of in-flight rounds (see module docs).
pub(crate) struct RoundRegistry {
    metrics: Arc<MetricsRegistry>,
    state: Mutex<State>,
    cv: Condvar,
}

impl RoundRegistry {
    pub fn new(metrics: Arc<MetricsRegistry>) -> Self {
        Self { metrics, state: Mutex::new(State::default()), cv: Condvar::new() }
    }

    /// Open a round *before* its orders go out, so results can never
    /// race the registration. `wait_for` starts unsatisfiable;
    /// [`finalize`](Self::finalize) installs the real counts once
    /// dispatch has settled.
    pub fn register(&self, round: u64, ctx: DecodeCtx, threshold: Threshold, started: Instant) {
        let mut st = self.state.lock().unwrap();
        st.rounds.insert(
            round,
            InflightRound {
                ctx,
                threshold,
                results: Vec::new(),
                wait_for: usize::MAX,
                policy_wait: usize::MAX,
                min_required: 0,
                dispatched: 0,
                pending: Vec::new(),
                lost: Vec::new(),
                spec_pending: Vec::new(),
                spec_dup: Vec::new(),
                degraded: false,
                hopeless: None,
                spilled: 0,
                sizes: Vec::new(),
                started,
            },
        );
    }

    /// Install the real wait/dispatch counts after the dispatch loop.
    /// `sent` lists the workers whose orders actually went out; the ones
    /// that have not already responded become the round's pending set.
    /// Early arrivals beyond `wait_for` (possible when workers respond
    /// mid-dispatch) spill into the wasted-work accounting, keeping the
    /// decode input at exactly the first `wait_for` arrivals.
    pub fn finalize(&self, round: u64, wait_for: usize, min_required: usize, sent: &[usize]) {
        let mut st = self.state.lock().unwrap();
        if let Some(r) = st.rounds.get_mut(&round) {
            r.wait_for = wait_for;
            r.policy_wait = wait_for;
            r.min_required = min_required;
            r.dispatched = sent.len();
            r.pending = sent
                .iter()
                .copied()
                .filter(|w| !r.results.iter().any(|(rw, _)| rw == w))
                .collect();
            if r.results.len() > wait_for {
                let excess = r.results.len() - wait_for;
                r.results.truncate(wait_for);
                r.sizes.truncate(wait_for);
                r.spilled += excess;
                self.metrics.add(names::RESULTS_LATE, excess as u64);
            }
            if r.results.len() >= r.wait_for {
                self.cv.notify_all();
            }
        }
    }

    /// Would a result for `round` be buffered right now? The collector
    /// uses this as a cheap pre-check so rejected results are never
    /// unsealed (wasted crypto) or charged to the comm counters.
    pub fn would_accept(&self, round: u64) -> bool {
        let st = self.state.lock().unwrap();
        matches!(st.rounds.get(&round), Some(r) if r.results.len() < r.wait_for)
    }

    /// Settle a result that will not be buffered: spilled (round in
    /// flight but frozen) or late (round gone) — wasted work either way.
    pub fn note_rejected(&self, round: u64) {
        let mut st = self.state.lock().unwrap();
        self.metrics.inc(names::RESULTS_LATE);
        match st.rounds.get_mut(&round) {
            Some(r) => r.spilled += 1,
            None => Self::settle_outstanding(&mut st, round),
        }
    }

    /// The master learned that `worker`'s result for `round` will never
    /// arrive (scheduled crash, corrupted frame): move it from the
    /// pending set to the lost set and re-evaluate the round (degrade or
    /// go hopeless — see module docs).
    pub fn note_lost(&self, round: u64, worker: usize) {
        let mut st = self.state.lock().unwrap();
        if let Some(r) = st.rounds.get_mut(&round) {
            if Self::write_off(r, worker) {
                self.reevaluate(r);
            }
        }
    }

    /// The master learned `worker` is down entirely (dead link, crash
    /// without respawn yet): every in-flight round that still expected a
    /// result from it re-evaluates.
    pub fn note_worker_down(&self, worker: usize) {
        let mut st = self.state.lock().unwrap();
        for r in st.rounds.values_mut() {
            if Self::write_off(r, worker) {
                self.reevaluate(r);
            }
        }
    }

    /// Move `share` pending → lost; true when it was in fact pending.
    fn write_off(r: &mut InflightRound, share: usize) -> bool {
        let before = r.pending.len();
        r.pending.retain(|&p| p != share);
        if r.pending.len() == before {
            return false;
        }
        if !r.lost.contains(&share) {
            r.lost.push(share);
        }
        true
    }

    /// Re-derive a round's fate after its pending set changed (shrunk by
    /// a write-off, or grown back by a speculative re-dispatch).
    fn reevaluate(&self, r: &mut InflightRound) {
        if r.wait_for == usize::MAX {
            return; // not finalized yet: the policy is not known
        }
        if r.results.len() >= r.wait_for {
            return; // already satisfied
        }
        let possible = r.possible();
        if possible < r.min_required {
            // Exact schemes land here as soon as k is unreachable;
            // flexible schemes when even `min` is gone.
            r.hopeless = Some((possible, r.min_required));
            self.cv.notify_all();
            return;
        }
        // Reachable again (a speculative re-dispatch restored a share):
        // rescind a hopeless verdict the waiter has not consumed yet.
        r.hopeless = None;
        // Wait for as much of the original policy as can still arrive —
        // degrading on loss, restoring on recovery, never above the
        // policy and never below the scheme's floor.
        r.wait_for = possible.min(r.policy_wait).max(r.min_required);
        let degraded_now = r.wait_for < r.policy_wait;
        if degraded_now && !r.degraded {
            self.metrics.inc(names::ROUNDS_DEGRADED);
        }
        r.degraded = degraded_now;
        if r.results.len() >= r.wait_for {
            self.cv.notify_all();
        }
    }

    /// Rounds with written-off shares a speculative pass could recover:
    /// `(round, lost shares)` for every in-flight finalized round.
    pub fn speculation_candidates(&self) -> Vec<(u64, Vec<usize>)> {
        let st = self.state.lock().unwrap();
        let mut out: Vec<(u64, Vec<usize>)> = st
            .rounds
            .iter()
            .filter(|(_, r)| r.wait_for != usize::MAX && !r.lost.is_empty())
            .map(|(&round, r)| (round, r.lost.clone()))
            .collect();
        out.sort_unstable();
        out
    }

    /// Shares still pending for `round` (empty when the round is gone) —
    /// the deadline-near duplication targets.
    pub fn pending_shares(&self, round: u64) -> Vec<usize> {
        let st = self.state.lock().unwrap();
        st.rounds.get(&round).map(|r| r.pending.clone()).unwrap_or_default()
    }

    /// Round ids currently in flight (for the master's bookkeeping
    /// sweeps).
    pub fn inflight_ids(&self) -> Vec<u64> {
        self.state.lock().unwrap().rounds.keys().copied().collect()
    }

    /// A lost share was re-dispatched to another worker: move it back to
    /// pending, mark it speculative, and re-evaluate (the wait target is
    /// restored toward the policy; a hopeless verdict is rescinded when
    /// the threshold is reachable again). False when the share is not
    /// eligible (round gone, share not lost, or already buffered).
    pub fn respeculate(&self, round: u64, share: usize) -> bool {
        let mut st = self.state.lock().unwrap();
        let Some(r) = st.rounds.get_mut(&round) else { return false };
        if r.wait_for == usize::MAX
            || !r.lost.contains(&share)
            || r.results.iter().any(|(s, _)| *s == share)
        {
            return false;
        }
        r.lost.retain(|&s| s != share);
        r.pending.push(share);
        if !r.spec_pending.contains(&share) {
            r.spec_pending.push(share);
        }
        r.dispatched += 1;
        self.reevaluate(r);
        true
    }

    /// A still-pending share was duplicated onto an idle worker near the
    /// deadline (first result wins). False when the share is not pending
    /// or already duplicated.
    pub fn respeculate_dup(&self, round: u64, share: usize) -> bool {
        let mut st = self.state.lock().unwrap();
        let Some(r) = st.rounds.get_mut(&round) else { return false };
        if !r.pending.contains(&share) || r.spec_dup.contains(&share) {
            return false;
        }
        r.spec_dup.push(share);
        r.dispatched += 1;
        true
    }

    /// Roll back a [`respeculate`](Self::respeculate) /
    /// [`respeculate_dup`](Self::respeculate_dup) whose dispatch failed
    /// (the order never left the master, so no result can race this): a
    /// duplicate simply forgets its marker; a recovery re-dispatch
    /// returns the share to the lost set and re-evaluates.
    pub fn respeculate_failed(&self, round: u64, share: usize) {
        let mut st = self.state.lock().unwrap();
        if let Some(r) = st.rounds.get_mut(&round) {
            if r.results.iter().any(|(s, _)| *s == share) {
                return;
            }
            if r.spec_dup.contains(&share) {
                r.spec_dup.retain(|&s| s != share);
                r.dispatched = r.dispatched.saturating_sub(1);
                return;
            }
            r.spec_pending.retain(|&s| s != share);
            if Self::write_off(r, share) {
                r.dispatched = r.dispatched.saturating_sub(1);
                self.reevaluate(r);
            }
        }
    }

    /// Deliver one decoded result for a share of `round` with its wire
    /// cost `(symbols, frame bytes)`: buffered under its in-flight round
    /// (waking waiters when the policy is satisfied), or counted as
    /// wasted work — a speculation loser (the share is already
    /// buffered), spilled (buffer frozen at `wait_for`), or late (round
    /// gone). Returns true when buffered. A result for a share
    /// previously written off (`note_lost`) is still welcome — first
    /// copy wins, whichever worker computed it.
    pub fn deliver(
        &self,
        round: u64,
        share: usize,
        result: Matrix,
        symbols: u64,
        frame_bytes: u64,
    ) -> bool {
        let mut st = self.state.lock().unwrap();
        match st.rounds.get_mut(&round) {
            Some(r) if r.results.iter().any(|(s, _)| *s == share) => {
                // A duplicate copy of an already-buffered share: the
                // losing side of first-result-wins. Deterministic by
                // construction — both copies carry identical bits, so
                // which one was "first" never changes the decode input.
                r.pending.retain(|&p| p != share);
                r.spec_dup.retain(|&s| s != share);
                r.spec_pending.retain(|&s| s != share);
                r.spilled += 1;
                self.metrics.inc(names::SPEC_WASTED);
                false
            }
            Some(r) if r.results.len() >= r.wait_for => {
                // Policy already satisfied: frozen buffer, wasted work.
                Self::forget_share(r, share);
                r.spilled += 1;
                self.metrics.inc(names::RESULTS_LATE);
                false
            }
            Some(r) => {
                let recovered = r.spec_pending.contains(&share);
                Self::forget_share(r, share);
                r.results.push((share, result));
                r.sizes.push((symbols, frame_bytes));
                if recovered {
                    self.metrics.inc(names::SPEC_RECOVERED);
                }
                if r.results.len() >= r.wait_for {
                    self.cv.notify_all();
                }
                true
            }
            None => {
                self.metrics.inc(names::RESULTS_LATE);
                Self::settle_outstanding(&mut st, round);
                false
            }
        }
    }

    /// Drop `share` from every expectation set of `r`.
    fn forget_share(r: &mut InflightRound, share: usize) {
        r.pending.retain(|&p| p != share);
        r.lost.retain(|&s| s != share);
        r.spec_pending.retain(|&s| s != share);
        r.spec_dup.retain(|&s| s != share);
    }

    /// One expected-but-unbuffered result landed for a settled round;
    /// drop its entry once nothing more is expected (keeps the
    /// late-arrival map from growing forever).
    fn settle_outstanding(st: &mut State, round: u64) {
        if let Some(left) = st.outstanding.get_mut(&round) {
            *left = left.saturating_sub(1);
            if *left == 0 {
                st.outstanding.remove(&round);
            }
        }
    }

    /// Block until `round` satisfies its wait policy, or until
    /// `deadline`, or until the round becomes hopeless (see module
    /// docs). On timeout or hopelessness the round is abandoned in place
    /// (its buffered results become wasted work) so late arrivals go
    /// through the stale path instead of accumulating forever.
    pub fn wait_done(&self, round: u64, deadline: Instant) -> Result<InflightRound, WaitError> {
        let mut st = self.state.lock().unwrap();
        loop {
            match st.rounds.get(&round) {
                None => return Err(WaitError::Unknown(round)),
                Some(r) if r.results.len() >= r.wait_for => {
                    return Ok(Self::retire(&mut st, round));
                }
                Some(r) => {
                    if let Some((possible, need)) = r.hopeless {
                        Self::drop_round(&mut st, &self.metrics, round);
                        return Err(WaitError::Hopeless { round, possible, need });
                    }
                }
            }
            let now = Instant::now();
            if now >= deadline {
                let (got, need) = match st.rounds.get(&round) {
                    Some(r) => (r.results.len(), r.wait_for),
                    None => (0, 0),
                };
                Self::drop_round(&mut st, &self.metrics, round);
                return Err(WaitError::TimedOut { round, got, need });
            }
            let (guard, _) = self.cv.wait_timeout(st, deadline - now).unwrap();
            st = guard;
        }
    }

    /// Block until `round` completes or `until` passes — the
    /// speculation checkpoint. Unlike [`wait_done`](Self::wait_done),
    /// reaching `until` (or a hopeless verdict) abandons *nothing*: the
    /// caller gets the still-outstanding shares back and decides what to
    /// re-dispatch before settling in for the hard deadline.
    pub fn wait_soft(&self, round: u64, until: Instant) -> SoftWait {
        let mut st = self.state.lock().unwrap();
        loop {
            match st.rounds.get(&round) {
                None => return SoftWait::Gone,
                Some(r) if r.results.len() >= r.wait_for => {
                    return SoftWait::Done(Self::retire(&mut st, round));
                }
                Some(r) if r.hopeless.is_some() => {
                    return SoftWait::Blocked { pending: r.pending.clone(), hopeless: true };
                }
                Some(_) => {}
            }
            let now = Instant::now();
            if now >= until {
                let pending =
                    st.rounds.get(&round).map(|r| r.pending.clone()).unwrap_or_default();
                return SoftWait::Blocked { pending, hopeless: false };
            }
            let (guard, _) = self.cv.wait_timeout(st, until - now).unwrap();
            st = guard;
        }
    }

    /// Remove a satisfied round, parking its never-arrived remainder in
    /// the late-arrival accounting.
    fn retire(st: &mut State, round: u64) -> InflightRound {
        let done = st.rounds.remove(&round).expect("caller checked the round is satisfied");
        let received = done.results.len() + done.spilled;
        let remaining = done.dispatched.saturating_sub(received);
        if remaining > 0 {
            st.outstanding.insert(round, remaining);
        }
        done
    }

    /// Abandon a round (explicit `abandon`, or a dropped handle):
    /// buffered results are counted as wasted work and later arrivals go
    /// through the late accounting. Returns true if the round was still
    /// in flight.
    pub fn abandon(&self, round: u64) -> bool {
        let mut st = self.state.lock().unwrap();
        Self::drop_round(&mut st, &self.metrics, round)
    }

    /// Is the round still in flight?
    #[cfg(test)]
    pub fn is_inflight(&self, round: u64) -> bool {
        self.state.lock().unwrap().rounds.contains_key(&round)
    }

    fn drop_round(st: &mut State, metrics: &MetricsRegistry, round: u64) -> bool {
        if let Some(dead) = st.rounds.remove(&round) {
            let received = dead.results.len() + dead.spilled;
            let remaining = dead.dispatched.saturating_sub(received);
            if remaining > 0 {
                st.outstanding.insert(round, remaining);
            }
            metrics.add(names::RESULTS_LATE, dead.results.len() as u64);
            true
        } else {
            false
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::CodeParams;
    use crate::coding::TaskShape;
    use crate::config::SchemeKind;
    use crate::matrix::PartitionSpec;
    use std::time::Duration;

    fn registry() -> (Arc<RoundRegistry>, Arc<MetricsRegistry>) {
        let metrics = Arc::new(MetricsRegistry::new());
        (Arc::new(RoundRegistry::new(Arc::clone(&metrics))), metrics)
    }

    fn ctx() -> DecodeCtx {
        DecodeCtx {
            kind: SchemeKind::Uncoded,
            params: CodeParams::new(4, 4, 0),
            alphas: vec![],
            betas: vec![],
            spec: PartitionSpec::new(4, 4),
            degree: 1,
            shape: TaskShape::BlockMap,
        }
    }

    fn open(reg: &RoundRegistry, round: u64) {
        reg.register(round, ctx(), Threshold::Exact(1), Instant::now());
    }

    fn open_flexible(reg: &RoundRegistry, round: u64, min: usize) {
        reg.register(round, ctx(), Threshold::Flexible { min }, Instant::now());
    }

    fn sent(n: usize) -> Vec<usize> {
        (0..n).collect()
    }

    #[test]
    fn results_before_finalize_are_buffered_not_completing() {
        let (reg, _) = registry();
        open(&reg, 1);
        assert!(reg.deliver(1, 0, Matrix::ones(1, 1), 1, 64));
        // Unsatisfiable until finalize installs the real wait_for.
        let err = reg.wait_done(1, Instant::now()).unwrap_err();
        assert!(matches!(err, WaitError::TimedOut { round: 1, .. }));
    }

    #[test]
    fn wait_returns_once_policy_met_even_from_another_thread() {
        let (reg, _) = registry();
        open(&reg, 7);
        reg.finalize(7, 2, 1, &sent(4));
        let reg2 = Arc::clone(&reg);
        let j = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            reg2.deliver(7, 0, Matrix::ones(1, 1), 1, 64);
            reg2.deliver(7, 1, Matrix::ones(1, 1), 1, 64);
        });
        let done = reg.wait_done(7, Instant::now() + Duration::from_secs(5)).unwrap();
        assert_eq!(done.results.len(), 2);
        assert_eq!(done.dispatched, 4);
        j.join().unwrap();
        // Round is gone; a third delivery counts late.
        assert!(!reg.deliver(7, 2, Matrix::ones(1, 1), 1, 64));
    }

    #[test]
    fn timeout_abandons_and_counts_buffered_results_late() {
        let (reg, metrics) = registry();
        open(&reg, 3);
        reg.finalize(3, 5, 1, &sent(5));
        reg.deliver(3, 0, Matrix::ones(1, 1), 1, 64);
        let err = reg.wait_done(3, Instant::now() + Duration::from_millis(30)).unwrap_err();
        assert_eq!(err, WaitError::TimedOut { round: 3, got: 1, need: 5 });
        assert!(!reg.is_inflight(3));
        assert_eq!(metrics.get(names::RESULTS_LATE), 1);
    }

    #[test]
    fn waiting_twice_is_unknown() {
        let (reg, _) = registry();
        open(&reg, 9);
        reg.finalize(9, 0, 0, &sent(0)); // trivially satisfied
        reg.wait_done(9, Instant::now()).unwrap();
        assert_eq!(
            reg.wait_done(9, Instant::now()).unwrap_err(),
            WaitError::Unknown(9)
        );
    }

    #[test]
    fn buffer_freezes_at_wait_for() {
        let (reg, metrics) = registry();
        open(&reg, 5);
        reg.finalize(5, 2, 1, &sent(4));
        assert!(reg.deliver(5, 0, Matrix::ones(1, 1), 1, 64));
        assert!(reg.deliver(5, 1, Matrix::ones(1, 1), 1, 64));
        // Policy satisfied: the third arrival is wasted work, not a
        // bigger decode input.
        assert!(!reg.deliver(5, 2, Matrix::ones(1, 1), 1, 64));
        assert_eq!(metrics.get(names::RESULTS_LATE), 1);
        let done = reg.wait_done(5, Instant::now()).unwrap();
        assert_eq!(done.results.len(), 2, "decode input frozen at wait_for");
        assert_eq!(done.spilled, 1);
    }

    #[test]
    fn finalize_trims_early_overshoot() {
        let (reg, metrics) = registry();
        open(&reg, 6);
        // Workers responded mid-dispatch: three results before finalize.
        for w in 0..3 {
            assert!(reg.deliver(6, w, Matrix::ones(1, 1), 1, 64));
        }
        reg.finalize(6, 2, 1, &sent(4));
        let done = reg.wait_done(6, Instant::now()).unwrap();
        assert_eq!(done.results.len(), 2, "early overshoot must be trimmed");
        assert_eq!(done.spilled, 1);
        assert_eq!(metrics.get(names::RESULTS_LATE), 1);
    }

    #[test]
    fn would_accept_and_note_rejected_paths() {
        let (reg, metrics) = registry();
        open(&reg, 8);
        reg.finalize(8, 1, 1, &sent(2));
        assert!(reg.would_accept(8));
        assert!(reg.deliver(8, 0, Matrix::ones(1, 1), 3, 70));
        assert!(!reg.would_accept(8), "frozen buffer must reject");
        reg.note_rejected(8); // spilled while still in flight
        let done = reg.wait_done(8, Instant::now()).unwrap();
        assert_eq!(done.spilled, 1);
        assert_eq!(done.received_totals(), (3, 70));
        assert!(!reg.would_accept(8), "settled round must reject");
        reg.note_rejected(8); // late path
        assert_eq!(metrics.get(names::RESULTS_LATE), 2);
    }

    #[test]
    fn abandon_settles_accounting() {
        let (reg, metrics) = registry();
        open(&reg, 4);
        reg.finalize(4, 3, 1, &sent(3));
        reg.deliver(4, 0, Matrix::ones(1, 1), 1, 64);
        assert!(reg.abandon(4));
        assert!(!reg.abandon(4), "second abandon is a no-op");
        assert_eq!(metrics.get(names::RESULTS_LATE), 1);
        // The two never-delivered results now land through the stale path.
        assert!(!reg.deliver(4, 1, Matrix::ones(1, 1), 1, 64));
        assert_eq!(metrics.get(names::RESULTS_LATE), 2);
    }

    // ---- lifecycle churn -------------------------------------------------

    #[test]
    fn mid_round_loss_degrades_flexible_round_to_what_can_arrive() {
        let (reg, metrics) = registry();
        open_flexible(&reg, 10, 1);
        reg.finalize(10, 4, 1, &sent(4));
        reg.deliver(10, 0, Matrix::ones(1, 1), 1, 64);
        reg.deliver(10, 1, Matrix::ones(1, 1), 1, 64);
        // Workers 2 and 3 die mid-round: the policy (4) is unreachable,
        // but min (1) is already exceeded → decode from what arrived.
        reg.note_lost(10, 2);
        reg.note_worker_down(3);
        let done = reg.wait_done(10, Instant::now() + Duration::from_secs(5)).unwrap();
        assert_eq!(done.results.len(), 2);
        assert!(done.degraded, "the round must record its degradation");
        assert_eq!(done.wait_for, 2);
        assert_eq!(metrics.get(names::ROUNDS_DEGRADED), 1);
    }

    #[test]
    fn degraded_round_still_waits_for_remaining_pending() {
        let (reg, _) = registry();
        open_flexible(&reg, 11, 1);
        reg.finalize(11, 3, 1, &sent(3));
        reg.deliver(11, 0, Matrix::ones(1, 1), 1, 64);
        reg.note_lost(11, 1); // wait_for degrades 3 → 2; worker 2 still owes
        let reg2 = Arc::clone(&reg);
        let j = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            reg2.deliver(11, 2, Matrix::ones(1, 1), 1, 64);
        });
        let done = reg.wait_done(11, Instant::now() + Duration::from_secs(5)).unwrap();
        assert_eq!(done.results.len(), 2, "the straggling live worker is still waited for");
        j.join().unwrap();
    }

    #[test]
    fn exact_round_with_unreachable_threshold_is_hopeless_immediately() {
        let (reg, _) = registry();
        reg.register(20, ctx(), Threshold::Exact(3), Instant::now());
        reg.finalize(20, 3, 3, &sent(4));
        reg.deliver(20, 0, Matrix::ones(1, 1), 1, 64);
        reg.note_worker_down(1);
        reg.note_worker_down(2);
        // 1 buffered + 1 pending = 2 < k = 3 → hopeless, long before the
        // deadline.
        let t0 = Instant::now();
        let err = reg.wait_done(20, t0 + Duration::from_secs(30)).unwrap_err();
        assert_eq!(err, WaitError::Hopeless { round: 20, possible: 2, need: 3 });
        assert!(t0.elapsed() < Duration::from_secs(5), "must not ride the deadline");
        assert!(!reg.is_inflight(20));
    }

    #[test]
    fn flexible_round_below_min_is_hopeless() {
        let (reg, _) = registry();
        open_flexible(&reg, 21, 2);
        reg.finalize(21, 3, 2, &sent(3));
        reg.deliver(21, 0, Matrix::ones(1, 1), 1, 64);
        reg.note_worker_down(1);
        reg.note_worker_down(2);
        let err = reg.wait_done(21, Instant::now() + Duration::from_secs(30)).unwrap_err();
        assert_eq!(err, WaitError::Hopeless { round: 21, possible: 1, need: 2 });
    }

    #[test]
    fn result_from_a_written_off_worker_still_buffers() {
        // A worker the master wrote off (crash noted) manages to deliver
        // anyway — e.g. its result was already in flight, or it crashed
        // and rejoined mid-round. The registry welcomes the result.
        let (reg, _) = registry();
        open_flexible(&reg, 30, 1);
        reg.finalize(30, 3, 1, &sent(3));
        reg.note_lost(30, 2); // degrade 3 → 2
        assert!(reg.deliver(30, 2, Matrix::ones(1, 1), 1, 64), "written-off result welcome");
        assert!(reg.deliver(30, 0, Matrix::ones(1, 1), 1, 64));
        let done = reg.wait_done(30, Instant::now()).unwrap();
        assert_eq!(done.results.len(), 2);
        assert_eq!(done.results[0].0, 2);
        // note_lost for a worker that already delivered is a no-op.
        assert!(!reg.is_inflight(30));
    }

    #[test]
    fn abandon_while_respawning_settles_cleanly() {
        // A round is abandoned while one of its workers is mid-respawn:
        // the buffered result is wasted work, the never-arriving results
        // go through the late path, and nothing leaks.
        let (reg, metrics) = registry();
        open_flexible(&reg, 40, 1);
        reg.finalize(40, 3, 1, &sent(3));
        reg.deliver(40, 0, Matrix::ones(1, 1), 1, 64);
        reg.note_lost(40, 1); // crashed, respawn pending
        assert!(reg.abandon(40));
        assert_eq!(metrics.get(names::RESULTS_LATE), 1);
        // The respawned incarnation's late delivery (new generation, old
        // round id) settles through the stale path.
        assert!(!reg.deliver(40, 1, Matrix::ones(1, 1), 1, 64));
        assert!(!reg.deliver(40, 2, Matrix::ones(1, 1), 1, 64));
        assert_eq!(metrics.get(names::RESULTS_LATE), 3);
    }

    // ---- speculation ----------------------------------------------------

    #[test]
    fn respeculate_restores_the_wait_target_and_counts_recovery() {
        let (reg, metrics) = registry();
        open_flexible(&reg, 60, 1);
        reg.finalize(60, 4, 1, &sent(4));
        reg.deliver(60, 0, Matrix::ones(1, 1), 1, 64);
        reg.note_lost(60, 3); // degrade 4 → 3
        assert_eq!(reg.speculation_candidates(), vec![(60, vec![3])]);
        assert!(reg.respeculate(60, 3), "a lost share is eligible");
        assert!(!reg.respeculate(60, 3), "already back in pending");
        assert!(reg.speculation_candidates().is_empty());
        for w in [1, 2, 3] {
            reg.deliver(60, w, Matrix::ones(1, 1), 1, 64);
        }
        let done = reg.wait_done(60, Instant::now()).unwrap();
        assert_eq!(done.results.len(), 4, "the wait target was restored to the policy");
        assert!(!done.degraded, "a fully recovered round is not degraded");
        assert_eq!(metrics.get(names::SPEC_RECOVERED), 1);
        // The degradation was still observed while it lasted.
        assert_eq!(metrics.get(names::ROUNDS_DEGRADED), 1);
    }

    #[test]
    fn respeculate_rescinds_a_hopeless_verdict() {
        let (reg, _) = registry();
        reg.register(61, ctx(), Threshold::Exact(3), Instant::now());
        reg.finalize(61, 3, 3, &sent(3));
        reg.deliver(61, 0, Matrix::ones(1, 1), 1, 64);
        reg.note_worker_down(1); // possible 2 < 3 → hopeless
        assert!(reg.respeculate(61, 1));
        // Reachable again: the waiter must block, not fail fast.
        let reg2 = Arc::clone(&reg);
        let j = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            reg2.deliver(61, 1, Matrix::ones(1, 1), 1, 64);
            reg2.deliver(61, 2, Matrix::ones(1, 1), 1, 64);
        });
        let done = reg.wait_done(61, Instant::now() + Duration::from_secs(5)).unwrap();
        assert_eq!(done.results.len(), 3);
        j.join().unwrap();
    }

    #[test]
    fn duplicate_results_lose_first_result_wins() {
        let (reg, metrics) = registry();
        open_flexible(&reg, 62, 1);
        reg.finalize(62, 3, 1, &sent(3));
        assert!(reg.respeculate_dup(62, 2), "a pending share can be duplicated");
        assert!(!reg.respeculate_dup(62, 2), "but only once");
        assert!(reg.deliver(62, 2, Matrix::ones(1, 1), 1, 64), "first copy buffers");
        assert!(!reg.deliver(62, 2, Matrix::ones(1, 1), 1, 64), "second copy is discarded");
        assert_eq!(metrics.get(names::SPEC_WASTED), 1);
        reg.deliver(62, 0, Matrix::ones(1, 1), 1, 64);
        reg.deliver(62, 1, Matrix::ones(1, 1), 1, 64);
        let done = reg.wait_done(62, Instant::now()).unwrap();
        assert_eq!(done.results.len(), 3, "the duplicate never inflates the decode input");
        assert_eq!(done.dispatched, 4, "the duplicate order is accounted for");
    }

    #[test]
    fn failed_speculative_dispatch_rolls_back() {
        let (reg, _) = registry();
        open_flexible(&reg, 63, 1);
        reg.finalize(63, 3, 1, &sent(3));
        reg.note_lost(63, 1);
        assert!(reg.respeculate(63, 1));
        reg.respeculate_failed(63, 1);
        assert_eq!(reg.speculation_candidates(), vec![(63, vec![1])], "share is lost again");
        // Dup rollback keeps the share pending.
        assert!(reg.respeculate_dup(63, 2));
        reg.respeculate_failed(63, 2);
        assert_eq!(reg.pending_shares(63), vec![0, 2]);
        assert!(reg.respeculate_dup(63, 2), "the dup marker was cleared");
    }

    // ---- adversarial interleavings (property tests) ---------------------

    /// One seeded adversarial event applied to a registry.
    #[derive(Clone, Copy, Debug)]
    enum Ev {
        Deliver(usize),
        Duplicate(usize),
        Lost(usize),
        WorkerDown(usize),
        Respeculate(usize),
        /// A planned forgery handled the way the master handles it: the
        /// share is booked lost at submit (the collector will drop the
        /// forged frame at the commitment check) and immediately
        /// re-dispatched to an honest proxy — one atomic adversarial
        /// event, so it can land at any point of the interleaving:
        /// before the share delivered, after it delivered, after the
        /// round froze (DESIGN.md §11).
        ForgeRecover(usize),
        StaleDeliver(u64, usize),
    }

    /// Draw a seeded event script over `n` shares.
    fn script(g: &mut crate::prop::Gen, n: usize, len: usize) -> Vec<Ev> {
        (0..len)
            .map(|_| {
                let share = g.usize_in(0..n);
                match g.usize_in(0..9) {
                    0 | 1 | 2 => Ev::Deliver(share),
                    3 => Ev::Duplicate(share),
                    4 => Ev::Lost(share),
                    5 => Ev::WorkerDown(share),
                    6 => Ev::Respeculate(share),
                    7 => Ev::ForgeRecover(share),
                    _ => Ev::StaleDeliver(g.u64() | 1 << 40, share),
                }
            })
            .collect()
    }

    /// Apply a script and return the observable outcome fingerprint.
    fn apply(reg: &RoundRegistry, round: u64, evs: &[Ev]) -> (usize, Vec<usize>) {
        for &ev in evs {
            match ev {
                Ev::Deliver(s) => {
                    reg.deliver(round, s, Matrix::ones(1, 1), 1, 64);
                }
                Ev::Duplicate(s) => {
                    reg.respeculate_dup(round, s);
                    reg.deliver(round, s, Matrix::ones(1, 1), 1, 64);
                }
                Ev::Lost(s) => reg.note_lost(round, s),
                Ev::WorkerDown(s) => reg.note_worker_down(s),
                Ev::Respeculate(s) => {
                    reg.respeculate(round, s);
                }
                Ev::ForgeRecover(s) => {
                    reg.note_lost(round, s);
                    reg.respeculate(round, s);
                }
                Ev::StaleDeliver(r, s) => {
                    reg.deliver(r, s, Matrix::ones(1, 1), 1, 64);
                }
            }
        }
        match reg.wait_done(round, Instant::now()) {
            Ok(done) => {
                let mut used: Vec<usize> = done.results.iter().map(|(s, _)| *s).collect();
                let mut dedup = used.clone();
                dedup.sort_unstable();
                dedup.dedup();
                assert_eq!(dedup.len(), used.len(), "duplicate share in the decode input");
                used.sort_unstable();
                (done.results.len(), used)
            }
            Err(_) => (usize::MAX, Vec::new()),
        }
    }

    #[test]
    fn prop_interleavings_are_deterministic_and_leak_free() {
        use crate::prop::{forall, prop_assert};
        forall(120, 0x5EED_1, |g| {
            let n = g.usize_in(2..9);
            let wait_for = g.usize_in(1..n + 1);
            let min = g.usize_in(1..wait_for + 1);
            let evs = script(g, n, g.usize_in(1..24));
            let round = 7u64;
            // The same script against two fresh registries must land the
            // same `results_used` and the same share set — arrival-order
            // determinism is exactly what the digest pins.
            let (reg_a, _) = registry();
            open_flexible(&reg_a, round, min);
            reg_a.finalize(round, wait_for, min, &sent(n));
            let a = apply(&reg_a, round, &evs);
            let (reg_b, _) = registry();
            open_flexible(&reg_b, round, min);
            reg_b.finalize(round, wait_for, min, &sent(n));
            let b = apply(&reg_b, round, &evs);
            prop_assert(a == b, format!("outcome diverged: {a:?} vs {b:?} over {evs:?}"))?;
            // Post-retirement, nothing leaks: the round is gone (success
            // or not — a failed immediate wait abandons in place) and
            // late deliveries settle through the stale path.
            prop_assert(!reg_a.is_inflight(round), "round leaked past retirement")?;
            prop_assert(
                reg_a.pending_shares(round).is_empty(),
                "pending set leaked past retirement",
            )?;
            prop_assert(
                !reg_a.deliver(round, 0, Matrix::ones(1, 1), 1, 64),
                "a retired round buffered a late result",
            )?;
            prop_assert(
                reg_a.speculation_candidates().is_empty(),
                "lost set leaked past retirement",
            )
        });
    }

    #[test]
    fn prop_worker_down_racing_wait_never_wedges_or_double_counts() {
        use crate::prop::{forall, prop_assert};
        forall(40, 0x5EED_2, |g| {
            let n = g.usize_in(3..8);
            let round = 9u64;
            let (reg, _) = registry();
            open_flexible(&reg, round, 1);
            reg.finalize(round, n, 1, &sent(n));
            // One thread delivers results and kills a seeded subset of
            // workers in a seeded order while the main thread waits.
            let dead: Vec<usize> = g.subset(n, g.usize_in(1..n));
            let mut order: Vec<usize> = (0..n).collect();
            g.rng().shuffle(&mut order);
            let reg2 = Arc::clone(&reg);
            let dead2 = dead.clone();
            let j = std::thread::spawn(move || {
                for s in order {
                    if dead2.contains(&s) {
                        reg2.note_worker_down(s);
                    } else {
                        reg2.deliver(round, s, Matrix::ones(1, 1), 1, 64);
                    }
                }
            });
            let res = reg.wait_done(round, Instant::now() + Duration::from_secs(10));
            j.join().unwrap();
            // Every live worker's result is in; the dead are written off
            // — degraded decode, never a deadlock, never a duplicate.
            let done = match res {
                Ok(done) => done,
                Err(e) => return Err(format!("wait failed: {e:?}")),
            };
            prop_assert(
                done.results.len() == n - dead.len(),
                format!("used {} of n={n} with {} dead", done.results.len(), dead.len()),
            )?;
            prop_assert(!reg.is_inflight(round), "round leaked")?;
            prop_assert(
                done.results.iter().all(|(s, _)| !dead.contains(s)),
                "a dead worker's share was counted",
            )
        });
    }

    #[test]
    fn prop_forged_redispatch_racing_wait_converges_without_double_count() {
        use crate::prop::{forall, prop_assert};
        forall(40, 0x5EED_3, |g| {
            let n = g.usize_in(3..8);
            let round = 13u64;
            let (reg, metrics) = registry();
            open_flexible(&reg, round, 1);
            reg.finalize(round, n, 1, &sent(n));
            // A seeded subset of shares is forged. The master's sequence
            // is deterministic: booked lost at submit (the collector
            // will drop the forged frames at the commitment check) and
            // re-dispatched to honest proxies in the same pass — both
            // before the waiter blocks. Only the proxy *deliveries* race
            // the wait, in a seeded shuffled order.
            let forged: Vec<usize> = g.subset(n, g.usize_in(1..n));
            for &s in &forged {
                reg.note_lost(round, s);
                prop_assert(reg.respeculate(round, s), "a booked forgery is re-dispatchable")?;
            }
            let mut order: Vec<usize> = (0..n).collect();
            g.rng().shuffle(&mut order);
            let reg2 = Arc::clone(&reg);
            let j = std::thread::spawn(move || {
                for s in order {
                    reg2.deliver(round, s, Matrix::ones(1, 1), 1, 64);
                }
            });
            let res = reg.wait_done(round, Instant::now() + Duration::from_secs(10));
            j.join().unwrap();
            let done = match res {
                Ok(done) => done,
                Err(e) => return Err(format!("wait failed: {e:?}")),
            };
            // Every share arrives exactly once — forged ones through
            // their proxies — so the round converges to the full policy,
            // undegraded, with each recovery counted exactly once.
            prop_assert(
                done.results.len() == n,
                format!("used {} of n={n} with {} forged", done.results.len(), forged.len()),
            )?;
            prop_assert(!done.degraded, "a fully recovered round must not read degraded")?;
            prop_assert(
                metrics.get(names::SPEC_RECOVERED) == forged.len() as u64,
                format!(
                    "recovered {} for {} forged shares",
                    metrics.get(names::SPEC_RECOVERED),
                    forged.len()
                ),
            )?;
            prop_assert(!reg.is_inflight(round), "round leaked past retirement")
        });
    }

    #[test]
    fn crash_straddling_two_interleaved_rounds_hits_both() {
        // Two rounds in flight; worker 3 crashes once, mid-both. The
        // flexible round degrades; the exact round goes hopeless —
        // independent fates from one note_worker_down.
        let (reg, metrics) = registry();
        open_flexible(&reg, 50, 1);
        reg.finalize(50, 4, 1, &sent(4));
        reg.register(51, ctx(), Threshold::Exact(4), Instant::now());
        reg.finalize(51, 4, 4, &sent(4));
        for w in 0..3 {
            reg.deliver(50, w, Matrix::ones(1, 1), 1, 64);
            reg.deliver(51, w, Matrix::ones(1, 1), 1, 64);
        }
        reg.note_worker_down(3);
        let done = reg.wait_done(50, Instant::now() + Duration::from_secs(5)).unwrap();
        assert_eq!(done.results.len(), 3);
        assert!(done.degraded);
        let err = reg.wait_done(51, Instant::now() + Duration::from_secs(5)).unwrap_err();
        assert_eq!(err, WaitError::Hopeless { round: 51, possible: 3, need: 4 });
        assert_eq!(metrics.get(names::ROUNDS_DEGRADED), 1);
    }
}
