//! The in-flight round registry: the rendezvous between the submit
//! path, the background collector thread, and round handles.
//!
//! `Master::submit` registers a round before dispatching its orders; the
//! collector thread [`deliver`](RoundRegistry::deliver)s every decoded
//! result to its round (or the late-arrival accounting); `Master::wait`
//! blocks on the condvar until the round's wait policy is satisfied or
//! its deadline passes. Because delivery happens on the collector
//! thread, waiting on one round never stalls result intake for the
//! others, and a dropped [`RoundHandle`](super::RoundHandle) can settle
//! its round's accounting from wherever it is dropped.

use crate::coding::{DecodeCtx, Threshold};
use crate::matrix::Matrix;
use crate::metrics::{names, MetricsRegistry};
use std::collections::HashMap;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

/// Book-keeping for a submitted-but-undecoded round.
#[derive(Debug)]
pub(crate) struct InflightRound {
    /// Everything the decoder needs, produced at encode time.
    pub ctx: DecodeCtx,
    /// The scheme's recovery-threshold semantics for this round.
    pub threshold: Threshold,
    /// Decoded (worker, result) pairs buffered so far — capped at
    /// `wait_for`: once the policy is satisfied the buffer is frozen, so
    /// the decode input set is exactly the first `wait_for` arrivals
    /// (deterministic `results_used`, same as the old blocking recv loop).
    pub results: Vec<(usize, Matrix)>,
    /// How many results the wait policy needs.
    pub wait_for: usize,
    /// How many orders were actually dispatched.
    pub dispatched: usize,
    /// Results that arrived while in flight but after the buffer froze
    /// (already counted as wasted work).
    pub spilled: usize,
    /// Per-buffered-result (symbols, frame bytes), index-aligned with
    /// `results`. Fed to `comm.symbols_to_master` / `comm.bytes_rx` at
    /// decode time, so those counters reflect exactly the decode inputs
    /// — deterministic, like the old blocking recv loop.
    pub sizes: Vec<(u64, u64)>,
    /// Submit time (for the round's wall-clock).
    pub started: Instant,
}

impl InflightRound {
    /// Total (symbols, frame bytes) of the buffered results.
    pub fn received_totals(&self) -> (u64, u64) {
        self.sizes.iter().fold((0, 0), |(s, b), (ds, db)| (s + ds, b + db))
    }
}

/// Why a wait did not produce a round.
#[derive(Debug, PartialEq, Eq)]
pub(crate) enum WaitError {
    /// The round is not in flight (never submitted, already waited on,
    /// or abandoned).
    Unknown(u64),
    /// The deadline passed first; the round has been abandoned.
    TimedOut(u64),
}

#[derive(Default)]
struct State {
    rounds: HashMap<u64, InflightRound>,
    /// Completed/abandoned round → results still expected from workers
    /// (late-arrival accounting).
    outstanding: HashMap<u64, usize>,
}

/// Shared registry of in-flight rounds (see module docs).
pub(crate) struct RoundRegistry {
    metrics: Arc<MetricsRegistry>,
    state: Mutex<State>,
    cv: Condvar,
}

impl RoundRegistry {
    pub fn new(metrics: Arc<MetricsRegistry>) -> Self {
        Self { metrics, state: Mutex::new(State::default()), cv: Condvar::new() }
    }

    /// Open a round *before* its orders go out, so results can never
    /// race the registration. `wait_for` starts unsatisfiable;
    /// [`finalize`](Self::finalize) installs the real counts once
    /// dispatch has settled.
    pub fn register(&self, round: u64, ctx: DecodeCtx, threshold: Threshold, started: Instant) {
        let mut st = self.state.lock().unwrap();
        st.rounds.insert(
            round,
            InflightRound {
                ctx,
                threshold,
                results: Vec::new(),
                wait_for: usize::MAX,
                dispatched: 0,
                spilled: 0,
                sizes: Vec::new(),
                started,
            },
        );
    }

    /// Install the real wait/dispatch counts after the dispatch loop.
    /// Early arrivals beyond `wait_for` (possible when workers respond
    /// mid-dispatch) spill into the wasted-work accounting, keeping the
    /// decode input at exactly the first `wait_for` arrivals.
    pub fn finalize(&self, round: u64, wait_for: usize, dispatched: usize) {
        let mut st = self.state.lock().unwrap();
        if let Some(r) = st.rounds.get_mut(&round) {
            r.wait_for = wait_for;
            r.dispatched = dispatched;
            if r.results.len() > wait_for {
                let excess = r.results.len() - wait_for;
                r.results.truncate(wait_for);
                r.sizes.truncate(wait_for);
                r.spilled += excess;
                self.metrics.add(names::RESULTS_LATE, excess as u64);
            }
            if r.results.len() >= r.wait_for {
                self.cv.notify_all();
            }
        }
    }

    /// Would a result for `round` be buffered right now? The collector
    /// uses this as a cheap pre-check so rejected results are never
    /// unsealed (wasted crypto) or charged to the comm counters.
    pub fn would_accept(&self, round: u64) -> bool {
        let st = self.state.lock().unwrap();
        matches!(st.rounds.get(&round), Some(r) if r.results.len() < r.wait_for)
    }

    /// Settle a result that will not be buffered: spilled (round in
    /// flight but frozen) or late (round gone) — wasted work either way.
    pub fn note_rejected(&self, round: u64) {
        let mut st = self.state.lock().unwrap();
        self.metrics.inc(names::RESULTS_LATE);
        match st.rounds.get_mut(&round) {
            Some(r) => r.spilled += 1,
            None => Self::settle_outstanding(&mut st, round),
        }
    }

    /// Deliver one decoded worker result with its wire cost
    /// `(symbols, frame bytes)`: buffered under its in-flight round
    /// (waking waiters when the policy is satisfied), or counted as
    /// wasted work — spilled (buffer frozen at `wait_for`) or late
    /// (round gone). Returns true when buffered.
    pub fn deliver(
        &self,
        round: u64,
        worker: usize,
        result: Matrix,
        symbols: u64,
        frame_bytes: u64,
    ) -> bool {
        let mut st = self.state.lock().unwrap();
        match st.rounds.get_mut(&round) {
            Some(r) if r.results.len() >= r.wait_for => {
                // Policy already satisfied: frozen buffer, wasted work.
                r.spilled += 1;
                self.metrics.inc(names::RESULTS_LATE);
                false
            }
            Some(r) => {
                r.results.push((worker, result));
                r.sizes.push((symbols, frame_bytes));
                if r.results.len() >= r.wait_for {
                    self.cv.notify_all();
                }
                true
            }
            None => {
                self.metrics.inc(names::RESULTS_LATE);
                Self::settle_outstanding(&mut st, round);
                false
            }
        }
    }

    /// One expected-but-unbuffered result landed for a settled round;
    /// drop its entry once nothing more is expected (keeps the
    /// late-arrival map from growing forever).
    fn settle_outstanding(st: &mut State, round: u64) {
        if let Some(left) = st.outstanding.get_mut(&round) {
            *left = left.saturating_sub(1);
            if *left == 0 {
                st.outstanding.remove(&round);
            }
        }
    }

    /// Block until `round` satisfies its wait policy, or until
    /// `deadline`. On timeout the round is abandoned in place (its
    /// buffered results become wasted work) so late arrivals go through
    /// the stale path instead of accumulating forever.
    pub fn wait_done(&self, round: u64, deadline: Instant) -> Result<InflightRound, WaitError> {
        let mut st = self.state.lock().unwrap();
        loop {
            match st.rounds.get(&round) {
                None => return Err(WaitError::Unknown(round)),
                Some(r) if r.results.len() >= r.wait_for => {
                    let done = st.rounds.remove(&round).expect("checked above");
                    let received = done.results.len() + done.spilled;
                    let remaining = done.dispatched.saturating_sub(received);
                    if remaining > 0 {
                        st.outstanding.insert(round, remaining);
                    }
                    return Ok(done);
                }
                Some(_) => {}
            }
            let now = Instant::now();
            if now >= deadline {
                Self::drop_round(&mut st, &self.metrics, round);
                return Err(WaitError::TimedOut(round));
            }
            let (guard, _) = self.cv.wait_timeout(st, deadline - now).unwrap();
            st = guard;
        }
    }

    /// Abandon a round (explicit `abandon`, or a dropped handle):
    /// buffered results are counted as wasted work and later arrivals go
    /// through the late accounting. Returns true if the round was still
    /// in flight.
    pub fn abandon(&self, round: u64) -> bool {
        let mut st = self.state.lock().unwrap();
        Self::drop_round(&mut st, &self.metrics, round)
    }

    /// Is the round still in flight?
    #[cfg(test)]
    pub fn is_inflight(&self, round: u64) -> bool {
        self.state.lock().unwrap().rounds.contains_key(&round)
    }

    fn drop_round(st: &mut State, metrics: &MetricsRegistry, round: u64) -> bool {
        if let Some(dead) = st.rounds.remove(&round) {
            let received = dead.results.len() + dead.spilled;
            let remaining = dead.dispatched.saturating_sub(received);
            if remaining > 0 {
                st.outstanding.insert(round, remaining);
            }
            metrics.add(names::RESULTS_LATE, dead.results.len() as u64);
            true
        } else {
            false
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::CodeParams;
    use crate::coding::TaskShape;
    use crate::config::SchemeKind;
    use crate::matrix::PartitionSpec;
    use std::time::Duration;

    fn registry() -> (Arc<RoundRegistry>, Arc<MetricsRegistry>) {
        let metrics = Arc::new(MetricsRegistry::new());
        (Arc::new(RoundRegistry::new(Arc::clone(&metrics))), metrics)
    }

    fn ctx() -> DecodeCtx {
        DecodeCtx {
            kind: SchemeKind::Uncoded,
            params: CodeParams::new(4, 4, 0),
            alphas: vec![],
            betas: vec![],
            spec: PartitionSpec::new(4, 4),
            degree: 1,
            shape: TaskShape::BlockMap,
        }
    }

    fn open(reg: &RoundRegistry, round: u64) {
        reg.register(round, ctx(), Threshold::Exact(1), Instant::now());
    }

    #[test]
    fn results_before_finalize_are_buffered_not_completing() {
        let (reg, _) = registry();
        open(&reg, 1);
        assert!(reg.deliver(1, 0, Matrix::ones(1, 1), 1, 64));
        // Unsatisfiable until finalize installs the real wait_for.
        let err = reg.wait_done(1, Instant::now()).unwrap_err();
        assert_eq!(err, WaitError::TimedOut(1));
    }

    #[test]
    fn wait_returns_once_policy_met_even_from_another_thread() {
        let (reg, _) = registry();
        open(&reg, 7);
        reg.finalize(7, 2, 4);
        let reg2 = Arc::clone(&reg);
        let j = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            reg2.deliver(7, 0, Matrix::ones(1, 1), 1, 64);
            reg2.deliver(7, 1, Matrix::ones(1, 1), 1, 64);
        });
        let done = reg.wait_done(7, Instant::now() + Duration::from_secs(5)).unwrap();
        assert_eq!(done.results.len(), 2);
        assert_eq!(done.dispatched, 4);
        j.join().unwrap();
        // Round is gone; a third delivery counts late.
        assert!(!reg.deliver(7, 2, Matrix::ones(1, 1), 1, 64));
    }

    #[test]
    fn timeout_abandons_and_counts_buffered_results_late() {
        let (reg, metrics) = registry();
        open(&reg, 3);
        reg.finalize(3, 5, 5);
        reg.deliver(3, 0, Matrix::ones(1, 1), 1, 64);
        let err = reg.wait_done(3, Instant::now() + Duration::from_millis(30)).unwrap_err();
        assert_eq!(err, WaitError::TimedOut(3));
        assert!(!reg.is_inflight(3));
        assert_eq!(metrics.get(names::RESULTS_LATE), 1);
    }

    #[test]
    fn waiting_twice_is_unknown() {
        let (reg, _) = registry();
        open(&reg, 9);
        reg.finalize(9, 0, 0); // trivially satisfied
        reg.wait_done(9, Instant::now()).unwrap();
        assert_eq!(
            reg.wait_done(9, Instant::now()).unwrap_err(),
            WaitError::Unknown(9)
        );
    }

    #[test]
    fn buffer_freezes_at_wait_for() {
        let (reg, metrics) = registry();
        open(&reg, 5);
        reg.finalize(5, 2, 4);
        assert!(reg.deliver(5, 0, Matrix::ones(1, 1), 1, 64));
        assert!(reg.deliver(5, 1, Matrix::ones(1, 1), 1, 64));
        // Policy satisfied: the third arrival is wasted work, not a
        // bigger decode input.
        assert!(!reg.deliver(5, 2, Matrix::ones(1, 1), 1, 64));
        assert_eq!(metrics.get(names::RESULTS_LATE), 1);
        let done = reg.wait_done(5, Instant::now()).unwrap();
        assert_eq!(done.results.len(), 2, "decode input frozen at wait_for");
        assert_eq!(done.spilled, 1);
    }

    #[test]
    fn finalize_trims_early_overshoot() {
        let (reg, metrics) = registry();
        open(&reg, 6);
        // Workers responded mid-dispatch: three results before finalize.
        for w in 0..3 {
            assert!(reg.deliver(6, w, Matrix::ones(1, 1), 1, 64));
        }
        reg.finalize(6, 2, 4);
        let done = reg.wait_done(6, Instant::now()).unwrap();
        assert_eq!(done.results.len(), 2, "early overshoot must be trimmed");
        assert_eq!(done.spilled, 1);
        assert_eq!(metrics.get(names::RESULTS_LATE), 1);
    }

    #[test]
    fn would_accept_and_note_rejected_paths() {
        let (reg, metrics) = registry();
        open(&reg, 8);
        reg.finalize(8, 1, 2);
        assert!(reg.would_accept(8));
        assert!(reg.deliver(8, 0, Matrix::ones(1, 1), 3, 70));
        assert!(!reg.would_accept(8), "frozen buffer must reject");
        reg.note_rejected(8); // spilled while still in flight
        let done = reg.wait_done(8, Instant::now()).unwrap();
        assert_eq!(done.spilled, 1);
        assert_eq!(done.received_totals(), (3, 70));
        assert!(!reg.would_accept(8), "settled round must reject");
        reg.note_rejected(8); // late path
        assert_eq!(metrics.get(names::RESULTS_LATE), 2);
    }

    #[test]
    fn abandon_settles_accounting() {
        let (reg, metrics) = registry();
        open(&reg, 4);
        reg.finalize(4, 3, 3);
        reg.deliver(4, 0, Matrix::ones(1, 1), 1, 64);
        assert!(reg.abandon(4));
        assert!(!reg.abandon(4), "second abandon is a no-op");
        assert_eq!(metrics.get(names::RESULTS_LATE), 1);
        // The two never-delivered results now land through the stale path.
        assert!(!reg.deliver(4, 1, Matrix::ones(1, 1), 1, 64));
        assert_eq!(metrics.get(names::RESULTS_LATE), 2);
    }
}
