//! Matrix products: the compute hot path of the native (non-PJRT) route.
//!
//! The worker task of the paper's running example is the Gram product
//! `f(X̃) = X̃ X̃ᵀ` (§V-A); the DL trainer needs `A·B`, `A·Bᵀ` and
//! matrix–vector products. All products here use the same strategy:
//! pack the B operand so the inner loop walks both operands contiguously
//! (unit stride), then block over rows for cache reuse. This is the
//! "optimize the hot path" target of the §Perf pass — see
//! `benches/microbench.rs` for the naive-vs-blocked comparison.

use super::Matrix;

/// Row-block size for the outer blocking. 64 rows × 4 B × d floats keeps
/// a block of B-columns resident in L2 for the d values we use (≤ 4096).
const ROW_BLOCK: usize = 64;

/// `A (r×k) · B (k×c) → (r×c)`.
///
/// B is packed transposed once (O(kc)) so the inner product over `k`
/// reads both operands at unit stride.
pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols(), b.rows(), "matmul: inner dims {} vs {}", a.cols(), b.rows());
    let bt = b.transpose();
    matmul_tb(a, &bt)
}

/// `A (r×k) · Bᵀ where B is given as (c×k) → (r×c)`.
///
/// This is the natural layout for the Gram product and for the packed
/// general matmul. The inner kernel is an 8-wide unrolled dot product
/// with four independent accumulators (breaks the FP dependency chain so
/// the CPU can keep ≥2 FMAs in flight).
pub fn matmul_tb(a: &Matrix, b_t: &Matrix) -> Matrix {
    assert_eq!(a.cols(), b_t.cols(), "matmul_tb: inner dims {} vs {}", a.cols(), b_t.cols());
    let (r, k) = a.shape();
    let c = b_t.rows();
    let mut out = Matrix::zeros(r, c);

    for rb in (0..r).step_by(ROW_BLOCK) {
        let rend = (rb + ROW_BLOCK).min(r);
        for i in rb..rend {
            let arow = a.row(i);
            let orow = &mut out.as_mut_slice()[i * c..(i + 1) * c];
            for (j, o) in orow.iter_mut().enumerate() {
                *o = dot(arow, b_t.row(j));
            }
        }
    }
    let _ = k;
    out
}

/// Unrolled dot product with 4 accumulators.
#[inline]
fn dot(x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len());
    let n = x.len();
    let chunks = n / 8;
    let (mut s0, mut s1, mut s2, mut s3) = (0f32, 0f32, 0f32, 0f32);
    for i in 0..chunks {
        let o = i * 8;
        s0 += x[o] * y[o] + x[o + 4] * y[o + 4];
        s1 += x[o + 1] * y[o + 1] + x[o + 5] * y[o + 5];
        s2 += x[o + 2] * y[o + 2] + x[o + 6] * y[o + 6];
        s3 += x[o + 3] * y[o + 3] + x[o + 7] * y[o + 7];
    }
    let mut tail = 0f32;
    for i in chunks * 8..n {
        tail += x[i] * y[i];
    }
    (s0 + s1) + (s2 + s3) + tail
}

/// Gram product `X · Xᵀ` — the paper's worker task `f`.
///
/// Exploits symmetry: computes the upper triangle and mirrors, ~2×
/// fewer dot products than the general path.
pub fn gram(x: &Matrix) -> Matrix {
    let n = x.rows();
    let mut out = Matrix::zeros(n, n);
    for i in 0..n {
        let ri = x.row(i);
        for j in i..n {
            let v = dot(ri, x.row(j));
            out.set(i, j, v);
            out.set(j, i, v);
        }
    }
    out
}

/// Matrix–vector product `A (r×k) · v (k) → (r)`.
pub fn matvec(a: &Matrix, v: &[f32]) -> Vec<f32> {
    assert_eq!(a.cols(), v.len(), "matvec: dims {} vs {}", a.cols(), v.len());
    (0..a.rows()).map(|i| dot(a.row(i), v)).collect()
}

/// Naive triple-loop matmul — kept as the correctness oracle and the
/// "before" side of the §Perf comparison. Not used on any hot path.
pub fn matmul_naive(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols(), b.rows(), "matmul_naive: inner dims");
    let (r, k) = a.shape();
    let c = b.cols();
    let mut out = Matrix::zeros(r, c);
    for i in 0..r {
        for j in 0..c {
            let mut s = 0f32;
            for l in 0..k {
                s += a.get(i, l) * b.get(l, j);
            }
            out.set(i, j, s);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::rng_from_seed;

    #[test]
    fn matmul_matches_naive_random() {
        let mut r = rng_from_seed(10);
        for &(m, k, n) in &[(1usize, 1usize, 1usize), (3, 5, 2), (17, 9, 23), (64, 33, 65)] {
            let a = Matrix::random_uniform(m, k, -1.0, 1.0, &mut r);
            let b = Matrix::random_uniform(k, n, -1.0, 1.0, &mut r);
            let fast = matmul(&a, &b);
            let slow = matmul_naive(&a, &b);
            assert!(fast.max_abs_diff(&slow) < 1e-3, "shape ({m},{k},{n})");
        }
    }

    #[test]
    fn matmul_identity_is_noop() {
        let mut r = rng_from_seed(11);
        let a = Matrix::random_uniform(6, 6, -2.0, 2.0, &mut r);
        let i = Matrix::identity(6);
        assert!(matmul(&a, &i).max_abs_diff(&a) < 1e-6);
        assert!(matmul(&i, &a).max_abs_diff(&a) < 1e-6);
    }

    #[test]
    fn gram_matches_matmul_with_transpose() {
        let mut r = rng_from_seed(12);
        let x = Matrix::random_gaussian(20, 13, 0.0, 1.0, &mut r);
        let g1 = gram(&x);
        let g2 = matmul(&x, &x.transpose());
        assert!(g1.max_abs_diff(&g2) < 1e-3);
    }

    #[test]
    fn gram_is_symmetric_and_psd_diagonal() {
        let mut r = rng_from_seed(13);
        let x = Matrix::random_uniform(10, 7, -1.0, 1.0, &mut r);
        let g = gram(&x);
        for i in 0..10 {
            assert!(g.get(i, i) >= 0.0, "diagonal of Gram must be ≥ 0");
            for j in 0..10 {
                assert_eq!(g.get(i, j), g.get(j, i));
            }
        }
    }

    #[test]
    fn matvec_matches_matmul_column() {
        let mut r = rng_from_seed(14);
        let a = Matrix::random_uniform(9, 4, -1.0, 1.0, &mut r);
        let v: Vec<f32> = (0..4).map(|_| r.next_f32()).collect();
        let got = matvec(&a, &v);
        let vm = Matrix::from_vec(4, 1, v.clone());
        let expect = matmul(&a, &vm);
        for i in 0..9 {
            assert!((got[i] - expect.get(i, 0)).abs() < 1e-5);
        }
    }

    #[test]
    fn dot_handles_non_multiple_of_eight() {
        for n in 0..20 {
            let x: Vec<f32> = (0..n).map(|i| i as f32).collect();
            let y = vec![1f32; n];
            let expect: f32 = x.iter().sum();
            assert_eq!(super::dot(&x, &y), expect);
        }
    }
}
