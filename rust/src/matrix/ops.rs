//! Matrix products: the compute hot path of the native (non-PJRT) route.
//!
//! The worker task of the paper's running example is the Gram product
//! `f(X̃) = X̃ X̃ᵀ` (§V-A); the DL trainer needs `A·B`, `A·Bᵀ` and
//! matrix–vector products. All products go through one packed, blocked,
//! parallel kernel ([`matmul_tb`]): the B operand is packed transposed
//! once so the inner loop walks both operands at unit stride, the kernel
//! blocks over rows *and* columns for cache reuse, and the outer row
//! blocks run on the scoped thread pool ([`crate::parallel`]). Every
//! output element is produced by exactly one fixed-order dot product, so
//! results are bit-identical at any thread count. `matmul_naive` stays
//! as the correctness oracle and the "before" side of the §Perf
//! comparison (`benches/microbench.rs`).

use super::Matrix;
use crate::parallel::{self, ThreadPool};
use crate::simd;

/// Rows of A per parallel granule. 32 rows × 4 B × d floats keeps the A
/// panel comfortably in L2 for the d values we use (≤ 4096) while giving
/// the pool enough granules to balance (a 512-row product splits 16
/// ways).
const ROW_BLOCK: usize = 32;

/// Rows of the packed Bᵀ operand per inner pass: a 64 × d panel
/// (≤ 1 MiB at d = 4096, 128 KiB at the DL shapes) stays hot across the
/// whole row block instead of being streamed from memory once per row.
const COL_BLOCK: usize = 64;

/// `A (r×k) · B (k×c) → (r×c)` on the globally configured pool.
///
/// B is packed transposed once (O(kc), cache-blocked) so the inner
/// product over `k` reads both operands at unit stride.
pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
    matmul_with(&parallel::global(), a, b)
}

/// [`matmul`] on an explicit pool (determinism tests pin widths).
pub fn matmul_with(pool: &ThreadPool, a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols(), b.rows(), "matmul: inner dims {} vs {}", a.cols(), b.rows());
    let bt = b.transpose();
    matmul_tb_with(pool, a, &bt)
}

/// `A (r×k) · Bᵀ where B is given as (c×k) → (r×c)` — the packed kernel.
///
/// This is the natural layout for the Gram product and for the packed
/// general matmul.
pub fn matmul_tb(a: &Matrix, b_t: &Matrix) -> Matrix {
    matmul_tb_with(&parallel::global(), a, b_t)
}

/// [`matmul_tb`] on an explicit pool.
///
/// Blocking: the output is split into [`ROW_BLOCK`]-row granules that the
/// pool distributes (disjoint output rows — no synchronization); inside a
/// granule the kernel iterates [`COL_BLOCK`]-row panels of the packed Bᵀ
/// so the panel is reused across every row of the granule. The inner
/// row-against-panel kernel is dispatched through [`crate::simd::gemm`]
/// (AVX2/NEON with the scalar 4-accumulator dot as oracle); every level
/// reproduces the same fixed reduction order, so outputs stay
/// bit-identical across thread counts *and* SIMD levels.
pub fn matmul_tb_with(pool: &ThreadPool, a: &Matrix, b_t: &Matrix) -> Matrix {
    assert_eq!(a.cols(), b_t.cols(), "matmul_tb: inner dims {} vs {}", a.cols(), b_t.cols());
    let (r, k) = a.shape();
    let c = b_t.rows();
    let mut out = Matrix::zeros(r, c);
    if r == 0 || c == 0 {
        return out;
    }
    let a_data = a.as_slice();
    let b_data = b_t.as_slice();
    pool.for_each_chunk(out.as_mut_slice(), ROW_BLOCK * c, |offset, chunk| {
        let row0 = offset / c;
        let rows = chunk.len() / c;
        for jb in (0..c).step_by(COL_BLOCK) {
            let jend = (jb + COL_BLOCK).min(c);
            for i in 0..rows {
                let arow = &a_data[(row0 + i) * k..(row0 + i) * k + k];
                let orow = &mut chunk[i * c..i * c + c];
                simd::gemm::row_panel(arow, &b_data[jb * k..jend * k], k, &mut orow[jb..jend]);
            }
        }
    });
    out
}

/// Gram product `X · Xᵀ` — the paper's worker task `f`.
///
/// Uses the packed kernel's row-granule layout (X is its own packed
/// operand) but keeps the symmetry saving: each granule computes only
/// the `j ≥ i` half of its rows, and a cheap serial mirror pass fills
/// the lower triangle — ~2× fewer dot products than the general kernel.
/// Still deterministic at any width: every element is produced by
/// exactly one fixed-order `dot`, and `dot(rᵢ, rⱼ)` is bitwise equal to
/// `dot(rⱼ, rᵢ)`, so the mirrored half is exactly what computing it
/// would have produced.
pub fn gram(x: &Matrix) -> Matrix {
    gram_with(&parallel::global(), x)
}

/// [`gram`] on an explicit pool.
pub fn gram_with(pool: &ThreadPool, x: &Matrix) -> Matrix {
    let (n, k) = x.shape();
    let mut out = Matrix::zeros(n, n);
    if n == 0 {
        return out;
    }
    let xd = x.as_slice();
    pool.for_each_chunk(out.as_mut_slice(), ROW_BLOCK * n, |offset, chunk| {
        let row0 = offset / n;
        let rows = chunk.len() / n;
        for i in 0..rows {
            let gi = row0 + i;
            let xrow = &xd[gi * k..gi * k + k];
            let orow = &mut chunk[i * n..i * n + n];
            simd::gemm::row_panel(xrow, &xd[gi * k..n * k], k, &mut orow[gi..n]);
        }
    });
    let data = out.as_mut_slice();
    for i in 1..n {
        for j in 0..i {
            data[i * n + j] = data[j * n + i];
        }
    }
    out
}

/// Matrix–vector product `A (r×k) · v (k) → (r)`.
///
/// Routed through the packed kernel ([`matmul_tb`]) with `v` as a
/// one-row packed operand: the product inherits the pool distribution
/// and the SIMD row kernel instead of the serial per-row loop it used
/// to run, and each output element is still one fixed-order dot — so
/// `matvec(a, v)` is bit-identical to column 0 of `matmul(a, vᵀ)`.
pub fn matvec(a: &Matrix, v: &[f32]) -> Vec<f32> {
    assert_eq!(a.cols(), v.len(), "matvec: dims {} vs {}", a.cols(), v.len());
    let vt = Matrix::from_vec(1, v.len(), v.to_vec());
    matmul_tb(a, &vt).as_slice().to_vec()
}

/// Naive triple-loop matmul — kept as the correctness oracle and the
/// "before" side of the §Perf comparison. Not used on any hot path.
pub fn matmul_naive(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols(), b.rows(), "matmul_naive: inner dims");
    let (r, k) = a.shape();
    let c = b.cols();
    let mut out = Matrix::zeros(r, c);
    for i in 0..r {
        for j in 0..c {
            let mut s = 0f32;
            for l in 0..k {
                s += a.get(i, l) * b.get(l, j);
            }
            out.set(i, j, s);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::rng_from_seed;

    #[test]
    fn matmul_matches_naive_random() {
        let mut r = rng_from_seed(10);
        for &(m, k, n) in &[(1usize, 1usize, 1usize), (3, 5, 2), (17, 9, 23), (64, 33, 65)] {
            let a = Matrix::random_uniform(m, k, -1.0, 1.0, &mut r);
            let b = Matrix::random_uniform(k, n, -1.0, 1.0, &mut r);
            let fast = matmul(&a, &b);
            let slow = matmul_naive(&a, &b);
            assert!(fast.max_abs_diff(&slow) < 1e-3, "shape ({m},{k},{n})");
        }
    }

    #[test]
    fn matmul_bit_identical_across_pool_widths() {
        let mut r = rng_from_seed(15);
        let a = Matrix::random_gaussian(70, 33, 0.0, 1.0, &mut r);
        let b = Matrix::random_gaussian(33, 41, 0.0, 1.0, &mut r);
        let serial = matmul_with(&ThreadPool::new(1), &a, &b);
        for threads in [2usize, 3, 8] {
            let par = matmul_with(&ThreadPool::new(threads), &a, &b);
            assert_eq!(
                serial.as_slice(),
                par.as_slice(),
                "threads={threads} must be bit-identical"
            );
        }
    }

    #[test]
    fn matmul_identity_is_noop() {
        let mut r = rng_from_seed(11);
        let a = Matrix::random_uniform(6, 6, -2.0, 2.0, &mut r);
        let i = Matrix::identity(6);
        assert!(matmul(&a, &i).max_abs_diff(&a) < 1e-6);
        assert!(matmul(&i, &a).max_abs_diff(&a) < 1e-6);
    }

    #[test]
    fn gram_matches_matmul_with_transpose() {
        let mut r = rng_from_seed(12);
        let x = Matrix::random_gaussian(20, 13, 0.0, 1.0, &mut r);
        let g1 = gram(&x);
        let g2 = matmul(&x, &x.transpose());
        assert!(g1.max_abs_diff(&g2) < 1e-3);
    }

    #[test]
    fn gram_is_symmetric_and_psd_diagonal() {
        let mut r = rng_from_seed(13);
        let x = Matrix::random_uniform(10, 7, -1.0, 1.0, &mut r);
        let g = gram(&x);
        for i in 0..10 {
            assert!(g.get(i, i) >= 0.0, "diagonal of Gram must be ≥ 0");
            for j in 0..10 {
                assert_eq!(g.get(i, j), g.get(j, i));
            }
        }
    }

    #[test]
    fn degenerate_shapes_are_handled() {
        let a = Matrix::zeros(0, 5);
        let b = Matrix::zeros(5, 3);
        assert_eq!(matmul(&a, &b).shape(), (0, 3));
        let a = Matrix::zeros(4, 0);
        let b = Matrix::zeros(0, 3);
        let out = matmul(&a, &b);
        assert_eq!(out.shape(), (4, 3));
        assert!(out.as_slice().iter().all(|&v| v == 0.0));
        let a = Matrix::ones(2, 3);
        let b = Matrix::zeros(3, 0);
        assert_eq!(matmul(&a, &b).shape(), (2, 0));
    }

    #[test]
    fn matvec_matches_matmul_column() {
        let mut r = rng_from_seed(14);
        let a = Matrix::random_uniform(9, 4, -1.0, 1.0, &mut r);
        let v: Vec<f32> = (0..4).map(|_| r.next_f32()).collect();
        let got = matvec(&a, &v);
        let vm = Matrix::from_vec(4, 1, v.clone());
        let expect = matmul(&a, &vm);
        for i in 0..9 {
            assert!((got[i] - expect.get(i, 0)).abs() < 1e-5);
        }
    }

    #[test]
    fn matvec_bit_identical_to_matmul_column() {
        // matvec routes through the packed kernel; against `matmul` with
        // the explicit k×1 operand the result must be bit-equal, not
        // merely close.
        let mut r = rng_from_seed(16);
        let a = Matrix::random_gaussian(33, 21, 0.0, 1.0, &mut r);
        let v: Vec<f32> = (0..21).map(|_| r.next_f32()).collect();
        let got = matvec(&a, &v);
        let expect = matmul(&a, &Matrix::from_vec(21, 1, v.clone()));
        assert_eq!(got, expect.as_slice());
    }
}
