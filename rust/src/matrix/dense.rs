//! Row-major dense `f32` matrix.

use crate::rng::Rng;

/// A dense row-major matrix of `f32`.
///
/// `f32` matches the HLO artifacts on the PJRT path; accumulations that
/// are numerically delicate (norms, losses, Berrut decode weights) are
/// done in `f64` internally.
#[derive(Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl Matrix {
    /// All-zeros matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// All-ones matrix (the paper's `I_{m,d}` mask carrier in §IV-B).
    pub fn ones(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![1.0; rows * cols] }
    }

    /// Identity (square).
    pub fn identity(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    /// Build from a closure over (row, col).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        Self { rows, cols, data }
    }

    /// Wrap an existing row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "buffer size mismatch");
        Self { rows, cols, data }
    }

    /// Uniform random entries in [lo, hi).
    pub fn random_uniform(rows: usize, cols: usize, lo: f32, hi: f32, rng: &mut Rng) -> Self {
        let data = (0..rows * cols).map(|_| lo + (hi - lo) * rng.next_f32()).collect();
        Self { rows, cols, data }
    }

    /// Gaussian random entries.
    pub fn random_gaussian(rows: usize, cols: usize, mean: f32, std: f32, rng: &mut Rng) -> Self {
        let data =
            (0..rows * cols).map(|_| rng.gaussian_with(mean as f64, std as f64) as f32).collect();
        Self { rows, cols, data }
    }

    /// Number of rows.
    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Number of columns.
    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// (rows, cols).
    #[inline]
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    /// Total element count.
    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True iff the matrix has no elements.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Element accessor.
    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    /// Mutable element accessor.
    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c] = v;
    }

    /// Raw row-major slice.
    #[inline]
    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    /// Raw mutable row-major slice.
    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// A single row as a slice.
    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Consume into the raw buffer.
    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Elementwise `self + rhs`.
    pub fn add(&self, rhs: &Matrix) -> Matrix {
        assert_eq!(self.shape(), rhs.shape(), "add: shape mismatch");
        let data = self.data.iter().zip(&rhs.data).map(|(a, b)| a + b).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    /// Elementwise `self - rhs`.
    pub fn sub(&self, rhs: &Matrix) -> Matrix {
        assert_eq!(self.shape(), rhs.shape(), "sub: shape mismatch");
        let data = self.data.iter().zip(&rhs.data).map(|(a, b)| a - b).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    /// Scalar multiply.
    pub fn scale(&self, s: f32) -> Matrix {
        let data = self.data.iter().map(|a| a * s).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    /// In-place `self += alpha * rhs` (the encode inner loop).
    pub fn axpy(&mut self, alpha: f32, rhs: &Matrix) {
        assert_eq!(self.shape(), rhs.shape(), "axpy: shape mismatch");
        for (a, b) in self.data.iter_mut().zip(&rhs.data) {
            *a += alpha * b;
        }
    }

    /// Elementwise (Hadamard) product — `⊙` of Eq. (22).
    pub fn hadamard(&self, rhs: &Matrix) -> Matrix {
        assert_eq!(self.shape(), rhs.shape(), "hadamard: shape mismatch");
        let data = self.data.iter().zip(&rhs.data).map(|(a, b)| a * b).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    /// Transpose, cache-blocked: 32×32 tiles keep both the read and the
    /// strided write side inside L1 (a tile is 4 KiB twice over), which
    /// matters because the packed-GEMM path transposes its B operand on
    /// every call.
    pub fn transpose(&self) -> Matrix {
        const TILE: usize = 32;
        let mut out = Matrix::zeros(self.cols, self.rows);
        let (r, c) = (self.rows, self.cols);
        for rb in (0..r).step_by(TILE) {
            let rend = (rb + TILE).min(r);
            for cb in (0..c).step_by(TILE) {
                let cend = (cb + TILE).min(c);
                for i in rb..rend {
                    for j in cb..cend {
                        out.data[j * r + i] = self.data[i * c + j];
                    }
                }
            }
        }
        out
    }

    /// Map every element.
    pub fn map(&self, f: impl Fn(f32) -> f32) -> Matrix {
        let data = self.data.iter().map(|&x| f(x)).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    /// Frobenius norm (f64 accumulation).
    pub fn frobenius_norm(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }

    /// Max |aᵢⱼ − bᵢⱼ| between two matrices.
    pub fn max_abs_diff(&self, rhs: &Matrix) -> f64 {
        assert_eq!(self.shape(), rhs.shape(), "max_abs_diff: shape mismatch");
        self.data
            .iter()
            .zip(&rhs.data)
            .map(|(a, b)| ((a - b) as f64).abs())
            .fold(0.0, f64::max)
    }

    /// Relative Frobenius error ‖self − rhs‖ / ‖rhs‖ (decode-quality metric).
    pub fn rel_error(&self, reference: &Matrix) -> f64 {
        let denom = reference.frobenius_norm().max(1e-30);
        self.sub(reference).frobenius_norm() / denom
    }

    /// Extract rows [start, start+count).
    pub fn rows_slice(&self, start: usize, count: usize) -> Matrix {
        assert!(start + count <= self.rows, "rows_slice out of bounds");
        let data = self.data[start * self.cols..(start + count) * self.cols].to_vec();
        Matrix { rows: count, cols: self.cols, data }
    }
}

impl core::fmt::Debug for Matrix {
    fn fmt(&self, f: &mut core::fmt::Formatter<'_>) -> core::fmt::Result {
        write!(f, "Matrix({}x{})", self.rows, self.cols)?;
        if self.rows <= 8 && self.cols <= 8 {
            writeln!(f)?;
            for r in 0..self.rows {
                write!(f, "  [")?;
                for c in 0..self.cols {
                    write!(f, " {:8.4}", self.get(r, c))?;
                }
                writeln!(f, " ]")?;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::rng_from_seed;

    #[test]
    fn construct_and_index() {
        let m = Matrix::from_fn(3, 4, |r, c| (r * 10 + c) as f32);
        assert_eq!(m.shape(), (3, 4));
        assert_eq!(m.get(2, 3), 23.0);
        assert_eq!(m.row(1), &[10.0, 11.0, 12.0, 13.0]);
    }

    #[test]
    fn identity_times_behaviour_via_transpose() {
        let i = Matrix::identity(4);
        assert_eq!(i.transpose(), i);
    }

    #[test]
    fn add_sub_roundtrip() {
        let mut r = rng_from_seed(1);
        let a = Matrix::random_uniform(5, 7, -1.0, 1.0, &mut r);
        let b = Matrix::random_uniform(5, 7, -1.0, 1.0, &mut r);
        let back = a.add(&b).sub(&b);
        assert!(back.max_abs_diff(&a) < 1e-6);
    }

    #[test]
    fn axpy_matches_scale_add() {
        let mut r = rng_from_seed(2);
        let a = Matrix::random_uniform(4, 4, -1.0, 1.0, &mut r);
        let b = Matrix::random_uniform(4, 4, -1.0, 1.0, &mut r);
        let mut c = a.clone();
        c.axpy(0.5, &b);
        assert!(c.max_abs_diff(&a.add(&b.scale(0.5))) < 1e-6);
    }

    #[test]
    fn transpose_involution() {
        let mut r = rng_from_seed(3);
        let a = Matrix::random_gaussian(6, 3, 0.0, 1.0, &mut r);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn frobenius_norm_known_value() {
        let m = Matrix::from_vec(1, 2, vec![3.0, 4.0]);
        assert!((m.frobenius_norm() - 5.0).abs() < 1e-12);
    }

    #[test]
    fn rows_slice_extracts_expected_rows() {
        let m = Matrix::from_fn(6, 2, |r, _| r as f32);
        let s = m.rows_slice(2, 3);
        assert_eq!(s.shape(), (3, 2));
        assert_eq!(s.get(0, 0), 2.0);
        assert_eq!(s.get(2, 1), 4.0);
    }

    #[test]
    #[should_panic(expected = "shape mismatch")]
    fn add_shape_mismatch_panics() {
        let a = Matrix::zeros(2, 2);
        let b = Matrix::zeros(2, 3);
        let _ = a.add(&b);
    }
}
