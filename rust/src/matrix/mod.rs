//! Dense matrix substrate.
//!
//! Every coding scheme in the paper manipulates `m × d` real matrices:
//! partitioning into K row-blocks, linear combinations (encoding),
//! Gram products `X Xᵀ` (the paper's running worker task, §V-A), and the
//! DL layer products of §VI. No ndarray/BLAS is available in this
//! environment, so this module implements a row-major `f32` matrix with
//! cache-blocked, transpose-packed matmul (see `ops.rs`) plus the
//! partition/stack helpers the schemes need (`partition.rs`).

mod dense;
mod ops;
mod partition;

pub use dense::Matrix;
pub use ops::{
    gram, gram_with, matmul, matmul_naive, matmul_tb, matmul_tb_with, matmul_with, matvec,
};
pub use partition::{split_rows, stack_rows, PartitionSpec};
