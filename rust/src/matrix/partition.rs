//! Row-partitioning per §V-B Eq. (16): X ∈ F^{m×d} split into K equal
//! row-blocks, zero-padding the last block when K ∤ m.

use super::Matrix;

/// How a matrix was partitioned — needed to undo the padding on decode.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PartitionSpec {
    /// Original row count m.
    pub original_rows: usize,
    /// Number of blocks K.
    pub k: usize,
    /// Rows per block (⌈m/K⌉).
    pub block_rows: usize,
}

impl PartitionSpec {
    /// Compute the spec for splitting `m` rows into `k` blocks.
    pub fn new(original_rows: usize, k: usize) -> Self {
        assert!(k > 0, "K must be positive");
        assert!(original_rows > 0, "matrix must be non-empty");
        let block_rows = original_rows.div_ceil(k);
        Self { original_rows, k, block_rows }
    }

    /// Rows of padding added to the final block.
    pub fn padding(&self) -> usize {
        self.block_rows * self.k - self.original_rows
    }
}

/// Split `x` into K row-blocks of equal size (Eq. 16), zero-padding the
/// final block if `K ∤ m` (as the paper specifies).
pub fn split_rows(x: &Matrix, k: usize) -> (Vec<Matrix>, PartitionSpec) {
    let spec = PartitionSpec::new(x.rows(), k);
    let d = x.cols();
    let mut blocks = Vec::with_capacity(k);
    for b in 0..k {
        let start = b * spec.block_rows;
        let end = ((b + 1) * spec.block_rows).min(x.rows());
        let mut block = Matrix::zeros(spec.block_rows, d);
        if start < x.rows() {
            let have = end - start;
            block.as_mut_slice()[..have * d]
                .copy_from_slice(&x.as_slice()[start * d..end * d]);
        }
        blocks.push(block);
    }
    (blocks, spec)
}

/// Reassemble row-blocks into one matrix, dropping the padding rows.
pub fn stack_rows(blocks: &[Matrix], spec: &PartitionSpec) -> Matrix {
    assert_eq!(blocks.len(), spec.k, "stack_rows: block count mismatch");
    let d = blocks[0].cols();
    let mut out = Matrix::zeros(spec.original_rows, d);
    for (b, block) in blocks.iter().enumerate() {
        assert_eq!(block.shape(), (spec.block_rows, d), "stack_rows: block shape");
        let start = b * spec.block_rows;
        if start >= spec.original_rows {
            break;
        }
        let take = (spec.original_rows - start).min(spec.block_rows);
        out.as_mut_slice()[start * d..(start + take) * d]
            .copy_from_slice(&block.as_slice()[..take * d]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::rng_from_seed;

    #[test]
    fn split_stack_roundtrip_divisible() {
        let mut r = rng_from_seed(20);
        let x = Matrix::random_uniform(12, 5, -1.0, 1.0, &mut r);
        let (blocks, spec) = split_rows(&x, 4);
        assert_eq!(blocks.len(), 4);
        assert_eq!(spec.padding(), 0);
        assert_eq!(stack_rows(&blocks, &spec), x);
    }

    #[test]
    fn split_stack_roundtrip_with_padding() {
        let mut r = rng_from_seed(21);
        let x = Matrix::random_uniform(13, 3, -1.0, 1.0, &mut r);
        let (blocks, spec) = split_rows(&x, 4);
        assert_eq!(spec.block_rows, 4);
        assert_eq!(spec.padding(), 3);
        // Padded rows must be zero.
        let last = &blocks[3];
        for c in 0..3 {
            assert_eq!(last.get(1, c), 0.0);
            assert_eq!(last.get(2, c), 0.0);
            assert_eq!(last.get(3, c), 0.0);
        }
        assert_eq!(stack_rows(&blocks, &spec), x);
    }

    #[test]
    fn split_k1_is_identity() {
        let mut r = rng_from_seed(22);
        let x = Matrix::random_uniform(7, 2, -1.0, 1.0, &mut r);
        let (blocks, spec) = split_rows(&x, 1);
        assert_eq!(blocks.len(), 1);
        assert_eq!(blocks[0], x);
        assert_eq!(stack_rows(&blocks, &spec), x);
    }

    #[test]
    fn split_k_larger_than_rows() {
        let x = Matrix::ones(2, 2);
        let (blocks, spec) = split_rows(&x, 5);
        assert_eq!(blocks.len(), 5);
        assert_eq!(spec.block_rows, 1);
        assert_eq!(stack_rows(&blocks, &spec), x);
    }

    #[test]
    #[should_panic(expected = "K must be positive")]
    fn split_k0_panics() {
        let x = Matrix::ones(2, 2);
        let _ = split_rows(&x, 0);
    }
}
