//! Synthetic MNIST-like dataset.
//!
//! 10 classes, 784 features (28×28), generated as class templates plus
//! Gaussian noise. Deterministic from the seed, linearly separable enough
//! that accuracy curves show the convergence behaviour Figs. 3–4 measure,
//! and hard enough (overlapping templates, noise) that training takes
//! multiple epochs.

use crate::matrix::Matrix;
use crate::rng::{derive_seed, rng_from_seed, Rng};

/// A labelled classification dataset.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// Features, one example per row.
    pub x: Matrix,
    /// Integer labels.
    pub y: Vec<usize>,
    /// Number of classes.
    pub classes: usize,
}

impl Dataset {
    /// Generate `n` examples of `features`-dimensional data over
    /// `classes` classes (deterministic in `seed`).
    ///
    /// Each class has a sparse template of active pixels (like a digit's
    /// stroke pattern); examples are template + noise, clipped to [0, 1]
    /// like normalized pixel intensities.
    pub fn synthetic(n: usize, features: usize, classes: usize, seed: u64) -> Self {
        Self::synthetic_with_templates(n, features, classes, seed, seed)
    }

    /// Like [`Dataset::synthetic`] but with the class templates seeded
    /// separately from the samples — train/test splits share
    /// `template_seed` (same distribution) with different `sample_seed`s.
    pub fn synthetic_with_templates(
        n: usize,
        features: usize,
        classes: usize,
        template_seed: u64,
        sample_seed: u64,
    ) -> Self {
        let mut template_rng = rng_from_seed(derive_seed(template_seed, 0x7E3));
        // Class templates: ~20% of pixels active at ~0.8 intensity.
        let templates: Vec<Vec<f32>> = (0..classes)
            .map(|_| {
                (0..features)
                    .map(|_| {
                        if template_rng.next_f64() < 0.2 {
                            0.5 + 0.5 * template_rng.next_f32()
                        } else {
                            0.0
                        }
                    })
                    .collect()
            })
            .collect();

        let mut rng = rng_from_seed(derive_seed(sample_seed, 0xDA7A));
        let mut x = Matrix::zeros(n, features);
        let mut y = Vec::with_capacity(n);
        for i in 0..n {
            let class = rng.next_below(classes as u64) as usize;
            y.push(class);
            for j in 0..features {
                let noise = rng.gaussian_with(0.0, 0.25) as f32;
                let v = (templates[class][j] + noise).clamp(0.0, 1.0);
                x.set(i, j, v);
            }
        }
        Self { x, y, classes }
    }

    /// Number of examples.
    pub fn len(&self) -> usize {
        self.y.len()
    }

    /// True iff empty.
    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    /// Feature dimension.
    pub fn features(&self) -> usize {
        self.x.cols()
    }

    /// A mini-batch as (features^T : d×b, one-hot labels : classes×b).
    ///
    /// Column-major batches (one example per *column*) match the network
    /// convention a = σ(Θ·a_prev + b).
    pub fn batch(&self, indices: &[usize]) -> (Matrix, Matrix) {
        let d = self.features();
        let b = indices.len();
        let mut xs = Matrix::zeros(d, b);
        let mut ys = Matrix::zeros(self.classes, b);
        for (col, &i) in indices.iter().enumerate() {
            for j in 0..d {
                xs.set(j, col, self.x.get(i, j));
            }
            ys.set(self.y[i], col, 1.0);
        }
        (xs, ys)
    }

    /// Shuffled epoch order (deterministic per (seed, epoch)).
    pub fn epoch_order(&self, seed: u64, epoch: usize) -> Vec<usize> {
        let mut order: Vec<usize> = (0..self.len()).collect();
        let mut rng: Rng = rng_from_seed(derive_seed(seed, 0xE90C + epoch as u64));
        rng.shuffle(&mut order);
        order
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_generation() {
        let a = Dataset::synthetic(100, 784, 10, 1);
        let b = Dataset::synthetic(100, 784, 10, 1);
        assert_eq!(a.x.as_slice(), b.x.as_slice());
        assert_eq!(a.y, b.y);
    }

    #[test]
    fn values_are_normalized_pixels() {
        let d = Dataset::synthetic(50, 784, 10, 2);
        assert!(d.x.as_slice().iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    #[test]
    fn all_classes_appear() {
        let d = Dataset::synthetic(500, 784, 10, 3);
        let mut seen = [false; 10];
        for &c in &d.y {
            seen[c] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn batch_shapes_and_one_hot() {
        let d = Dataset::synthetic(20, 32, 4, 4);
        let (xs, ys) = d.batch(&[0, 5, 7]);
        assert_eq!(xs.shape(), (32, 3));
        assert_eq!(ys.shape(), (4, 3));
        for col in 0..3 {
            let sum: f32 = (0..4).map(|r| ys.get(r, col)).sum();
            assert_eq!(sum, 1.0, "one-hot column");
        }
    }

    #[test]
    fn classes_are_distinguishable() {
        // Mean intra-class distance should be smaller than inter-class.
        let d = Dataset::synthetic(200, 128, 4, 5);
        let by_class: Vec<Vec<usize>> = (0..4)
            .map(|c| (0..d.len()).filter(|&i| d.y[i] == c).collect())
            .collect();
        let dist = |i: usize, j: usize| -> f64 {
            d.x.row(i)
                .iter()
                .zip(d.x.row(j))
                .map(|(a, b)| ((a - b) as f64).powi(2))
                .sum::<f64>()
                .sqrt()
        };
        let intra = dist(by_class[0][0], by_class[0][1]);
        let inter = dist(by_class[0][0], by_class[1][0]);
        assert!(intra < inter, "intra {intra} vs inter {inter}");
    }

    #[test]
    fn epoch_orders_differ_by_epoch() {
        let d = Dataset::synthetic(64, 16, 4, 6);
        assert_ne!(d.epoch_order(1, 0), d.epoch_order(1, 1));
        assert_eq!(d.epoch_order(1, 0), d.epoch_order(1, 0));
    }
}
