//! SPACDC-DL — the paper's deep-learning application (§VI, Algorithm 2).
//!
//! * [`dataset`] — synthetic MNIST-like classification data (no network
//!   access in this environment; see DESIGN.md §3 for the substitution).
//! * [`network`] — the DNN of §VI-A: dense layers, forward/backward,
//!   SGD updates (Eqs. (19)–(22)).
//! * [`trainer`] — distributed training where the backward-pass matrix
//!   product of Eq. (23) is computed through the coded master/worker
//!   fabric, under any of the paper's four algorithms
//!   (CONV-DL, MDS-DL, MATDOT-DL, SPACDC-DL).

pub mod dataset;
pub mod network;
pub mod trainer;

pub use dataset::Dataset;
pub use network::{Network, TrainBatch};
pub use trainer::{train, EpochStats, TrainReport, TrainerOptions};
