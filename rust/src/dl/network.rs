//! The DNN of §VI-A: dense layers with column-major batches,
//! `aˡ = σ(Θˡ aˡ⁻¹ + bˡ)` (Eq. (19)), ReLU hidden activations, softmax +
//! cross-entropy at the output, SGD updates (Eq. (21)).
//!
//! The backward pass exposes its heavy matrix product — `(Θˡ)ᵀ · δˡ`
//! of Eq. (22)/(23) — through a pluggable multiplier so the trainer can
//! route it through the coded master/worker fabric. Everything else
//! (activations, Hadamard products, updates) stays on the master, exactly
//! as Algorithm 2 prescribes.

use crate::dl::dataset::Dataset;
use crate::matrix::{matmul, matmul_tb, Matrix};
use crate::rng::{derive_seed, rng_from_seed};

/// One dense layer.
#[derive(Clone, Debug)]
pub struct Layer {
    /// Weights Θ (out × in).
    pub w: Matrix,
    /// Bias b (out).
    pub b: Vec<f32>,
}

/// A mini-batch in network convention: features d×batch, one-hot labels
/// classes×batch.
#[derive(Clone, Debug)]
pub struct TrainBatch {
    /// Inputs (one example per column).
    pub x: Matrix,
    /// One-hot labels.
    pub y: Matrix,
}

/// Cached forward state for backprop.
pub struct ForwardState {
    /// a⁰ (input) .. a^L (output, post-softmax).
    pub activations: Vec<Matrix>,
    /// τ¹ .. τ^L (pre-activations).
    pub taus: Vec<Matrix>,
}

/// Per-layer gradients.
pub struct Gradients {
    /// dΘ per layer.
    pub dw: Vec<Matrix>,
    /// db per layer.
    pub db: Vec<Vec<f32>>,
}

/// The multiplier used for the backward product `(Θˡ⁺¹)ᵀ · δˡ⁺¹`.
/// Arguments: (layer index of Θ, Θ, δ). Returns the product.
pub type BackwardMul<'a> = dyn FnMut(usize, &Matrix, &Matrix) -> Matrix + 'a;

/// A multi-layer perceptron.
#[derive(Clone, Debug)]
pub struct Network {
    layers: Vec<Layer>,
    dims: Vec<usize>,
}

impl Network {
    /// He-initialized network with the given layer widths
    /// (input, hidden…, classes).
    pub fn new(dims: &[usize], seed: u64) -> Self {
        assert!(dims.len() >= 2, "need at least input and output layers");
        let mut layers = Vec::with_capacity(dims.len() - 1);
        for l in 0..dims.len() - 1 {
            let (fan_in, fan_out) = (dims[l], dims[l + 1]);
            let std = (2.0 / fan_in as f64).sqrt() as f32;
            let mut rng = rng_from_seed(derive_seed(seed, 0x11E7 + l as u64));
            layers.push(Layer {
                w: Matrix::random_gaussian(fan_out, fan_in, 0.0, std, &mut rng),
                b: vec![0.0; fan_out],
            });
        }
        Self { layers, dims: dims.to_vec() }
    }

    /// Layer widths.
    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    /// The layers (read access for the coded trainer).
    pub fn layers(&self) -> &[Layer] {
        &self.layers
    }

    /// Total parameter count.
    pub fn parameter_count(&self) -> usize {
        self.layers.iter().map(|l| l.w.len() + l.b.len()).sum()
    }

    /// Forward pass, caching activations for backprop.
    pub fn forward(&self, x: &Matrix) -> ForwardState {
        let mut activations = vec![x.clone()];
        let mut taus = Vec::with_capacity(self.layers.len());
        for (l, layer) in self.layers.iter().enumerate() {
            let mut tau = matmul(&layer.w, activations.last().unwrap());
            // + b (broadcast over columns)
            for r in 0..tau.rows() {
                for c in 0..tau.cols() {
                    let v = tau.get(r, c) + layer.b[r];
                    tau.set(r, c, v);
                }
            }
            let a = if l + 1 == self.layers.len() {
                softmax_cols(&tau)
            } else {
                tau.map(|v| v.max(0.0)) // ReLU
            };
            taus.push(tau);
            activations.push(a);
        }
        ForwardState { activations, taus }
    }

    /// Mean cross-entropy of the forward output against one-hot `y`.
    pub fn loss(&self, output: &Matrix, y: &Matrix) -> f64 {
        let b = y.cols();
        let mut loss = 0.0;
        for c in 0..b {
            for r in 0..y.rows() {
                if y.get(r, c) > 0.5 {
                    loss -= (output.get(r, c).max(1e-12) as f64).ln();
                }
            }
        }
        loss / b as f64
    }

    /// Backward pass with a custom multiplier for the Eq. (23) product.
    /// Returns (loss, gradients).
    pub fn backward_with(
        &self,
        fwd: &ForwardState,
        y: &Matrix,
        mm: &mut BackwardMul<'_>,
    ) -> (f64, Gradients) {
        let l_count = self.layers.len();
        let batch = y.cols() as f32;
        let output = fwd.activations.last().unwrap();
        let loss = self.loss(output, y);

        // δ^L for softmax-CE: (a^L − y).
        let mut delta = output.sub(y);
        let mut dw = vec![Matrix::zeros(1, 1); l_count];
        let mut db = vec![Vec::new(); l_count];

        for l in (0..l_count).rev() {
            // dΘˡ = δˡ (aˡ⁻¹)ᵀ / batch   (Eq. (21))
            dw[l] = matmul_tb(&delta, &fwd.activations[l]).scale(1.0 / batch);
            db[l] = (0..delta.rows())
                .map(|r| (0..delta.cols()).map(|c| delta.get(r, c)).sum::<f32>() / batch)
                .collect();
            if l > 0 {
                // δˡ⁻¹ = (Θˡ)ᵀ δˡ ⊙ σ'(τˡ⁻¹)   (Eq. (22)) — the heavy
                // product goes through the pluggable multiplier.
                let h = mm(l, &self.layers[l].w, &delta);
                let relu_grad = fwd.taus[l - 1].map(|v| if v > 0.0 { 1.0 } else { 0.0 });
                delta = h.hadamard(&relu_grad);
            }
        }
        (loss, Gradients { dw, db })
    }

    /// Backward with the local (uncoded) multiplier.
    pub fn backward(&self, fwd: &ForwardState, y: &Matrix) -> (f64, Gradients) {
        self.backward_with(fwd, y, &mut |_, w, delta| matmul(&w.transpose(), delta))
    }

    /// SGD step: Θ ← Θ − η·dΘ, b ← b − η·db  (Eq. (21)).
    pub fn apply(&mut self, grads: &Gradients, lr: f32) {
        for (l, layer) in self.layers.iter_mut().enumerate() {
            layer.w.axpy(-lr, &grads.dw[l]);
            for (bv, g) in layer.b.iter_mut().zip(&grads.db[l]) {
                *bv -= lr * g;
            }
        }
    }

    /// Classification accuracy over a dataset (batched).
    pub fn accuracy(&self, data: &Dataset, batch_size: usize) -> f64 {
        let mut correct = 0usize;
        let n = data.len();
        let mut i = 0;
        while i < n {
            let idx: Vec<usize> = (i..(i + batch_size).min(n)).collect();
            let (x, _) = data.batch(&idx);
            let out = self.forward(&x);
            let probs = out.activations.last().unwrap();
            for (col, &example) in idx.iter().enumerate() {
                let mut best = 0;
                for r in 1..probs.rows() {
                    if probs.get(r, col) > probs.get(best, col) {
                        best = r;
                    }
                }
                if best == data.y[example] {
                    correct += 1;
                }
            }
            i += batch_size;
        }
        correct as f64 / n as f64
    }
}

/// Column-wise softmax.
fn softmax_cols(m: &Matrix) -> Matrix {
    let mut out = Matrix::zeros(m.rows(), m.cols());
    for c in 0..m.cols() {
        let mut mx = f32::NEG_INFINITY;
        for r in 0..m.rows() {
            mx = mx.max(m.get(r, c));
        }
        let mut sum = 0f32;
        for r in 0..m.rows() {
            let e = (m.get(r, c) - mx).exp();
            out.set(r, c, e);
            sum += e;
        }
        for r in 0..m.rows() {
            out.set(r, c, out.get(r, c) / sum);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forward_shapes() {
        let net = Network::new(&[8, 16, 4], 1);
        let x = Matrix::ones(8, 5);
        let f = net.forward(&x);
        assert_eq!(f.activations.len(), 3);
        assert_eq!(f.activations[2].shape(), (4, 5));
        assert_eq!(f.taus[0].shape(), (16, 5));
    }

    #[test]
    fn softmax_columns_sum_to_one() {
        let net = Network::new(&[4, 3], 2);
        let x = Matrix::ones(4, 6);
        let f = net.forward(&x);
        let probs = f.activations.last().unwrap();
        for c in 0..6 {
            let s: f32 = (0..3).map(|r| probs.get(r, c)).sum();
            assert!((s - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn gradients_match_finite_differences() {
        // Numerically check dΘ for a tiny network.
        let mut net = Network::new(&[3, 4, 2], 3);
        let mut rng = rng_from_seed(4);
        let x = Matrix::random_uniform(3, 5, 0.0, 1.0, &mut rng);
        let mut y = Matrix::zeros(2, 5);
        for c in 0..5 {
            y.set(c % 2, c, 1.0);
        }
        let fwd = net.forward(&x);
        let (_, grads) = net.backward(&fwd, &y);

        let eps = 1e-3f32;
        for (r, c) in [(0usize, 0usize), (1, 2), (3, 1)] {
            let orig = net.layers[0].w.get(r, c);
            net.layers[0].w.set(r, c, orig + eps);
            let lp = net.loss(net.forward(&x).activations.last().unwrap(), &y);
            net.layers[0].w.set(r, c, orig - eps);
            let lm = net.loss(net.forward(&x).activations.last().unwrap(), &y);
            net.layers[0].w.set(r, c, orig);
            let numeric = ((lp - lm) / (2.0 * eps as f64)) as f32;
            let analytic = grads.dw[0].get(r, c);
            assert!(
                (numeric - analytic).abs() < 2e-2,
                "({r},{c}): numeric {numeric} vs analytic {analytic}"
            );
        }
    }

    #[test]
    fn custom_multiplier_is_invoked_per_hidden_layer() {
        let net = Network::new(&[6, 8, 7, 3], 5);
        let x = Matrix::ones(6, 2);
        let mut y = Matrix::zeros(3, 2);
        y.set(0, 0, 1.0);
        y.set(1, 1, 1.0);
        let fwd = net.forward(&x);
        let mut calls = Vec::new();
        let (_, _) = net.backward_with(&fwd, &y, &mut |l, w, d| {
            calls.push(l);
            matmul(&w.transpose(), d)
        });
        // Hidden products for layers 2 and 1 (never layer 0).
        assert_eq!(calls, vec![2, 1]);
    }

    #[test]
    fn training_reduces_loss_locally() {
        let data = Dataset::synthetic(256, 32, 4, 6);
        let mut net = Network::new(&[32, 24, 4], 7);
        let idx: Vec<usize> = (0..64).collect();
        let (x, y) = data.batch(&idx);
        let first_loss = {
            let f = net.forward(&x);
            net.loss(f.activations.last().unwrap(), &y)
        };
        for _ in 0..30 {
            let f = net.forward(&x);
            let (_, g) = net.backward(&f, &y);
            net.apply(&g, 0.1);
        }
        let f = net.forward(&x);
        let last_loss = net.loss(f.activations.last().unwrap(), &y);
        assert!(
            last_loss < first_loss * 0.5,
            "loss {first_loss} → {last_loss} did not halve"
        );
    }

    #[test]
    fn accuracy_improves_with_training() {
        let train = Dataset::synthetic_with_templates(512, 64, 4, 8, 80);
        let test = Dataset::synthetic_with_templates(128, 64, 4, 8, 81);
        let mut net = Network::new(&[64, 32, 4], 10);
        let before = net.accuracy(&test, 32);
        for epoch in 0..5 {
            let order = train.epoch_order(1, epoch);
            for chunk in order.chunks(32) {
                let (x, y) = train.batch(chunk);
                let f = net.forward(&x);
                let (_, g) = net.backward(&f, &y);
                net.apply(&g, 0.1);
            }
        }
        let after = net.accuracy(&test, 32);
        assert!(after > before + 0.2, "accuracy {before} → {after}");
        assert!(after > 0.7, "final accuracy {after}");
    }

    #[test]
    fn parameter_count_matches_dims() {
        let net = Network::new(&[784, 256, 128, 10], 1);
        let expect = 784 * 256 + 256 + 256 * 128 + 128 + 128 * 10 + 10;
        assert_eq!(net.parameter_count(), expect);
    }
}
