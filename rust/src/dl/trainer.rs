//! The distributed trainer — Algorithm 2 (SPACDC-DL) and the paper's
//! baselines (CONV-DL, MDS-DL, MATDOT-DL), selected by
//! `SystemConfig::scheme`.
//!
//! Per step: the master runs the forward pass locally, then routes every
//! hidden-layer backward product `(Θˡ)ᵀ·δˡ` (Eq. (23)) through the coded
//! master/worker fabric — encode → MEA-ECC seal → dispatch → collect
//! (scheme threshold) → decode — and finishes the update locally
//! (Eq. (21)). Wall-clock, loss, and test accuracy are recorded per
//! epoch; Figs. 3–4 are regenerated from these reports.

use crate::coding::CodedTask;
use crate::config::{SchemeKind, SystemConfig};
use crate::coordinator::{Service, ServiceConfig, SessionId, SessionOptions};
use crate::dl::dataset::Dataset;
use crate::dl::network::Network;
use crate::matrix::{matmul, Matrix};
use crate::runtime::Executor;
use std::time::Instant;

/// Trainer options.
#[derive(Clone)]
pub struct TrainerOptions {
    /// The full system config (cluster shape, scheme, DL params).
    pub cfg: SystemConfig,
    /// Evaluate test accuracy after each epoch (costs one test sweep).
    pub eval_each_epoch: bool,
    /// Cap on total optimizer steps (None = run all epochs).
    pub max_steps: Option<usize>,
    /// Optional executor override (e.g. PJRT-backed).
    pub executor: Option<Executor>,
}

impl TrainerOptions {
    /// Defaults from a config.
    pub fn new(cfg: SystemConfig) -> Self {
        Self { cfg, eval_each_epoch: true, max_steps: None, executor: None }
    }
}

/// Per-epoch statistics.
#[derive(Clone, Debug)]
pub struct EpochStats {
    /// Epoch index (0-based).
    pub epoch: usize,
    /// Mean training loss over the epoch.
    pub loss: f64,
    /// Test accuracy after the epoch (NaN if not evaluated).
    pub accuracy: f64,
    /// Cumulative wall-clock seconds since training started.
    pub wall_s: f64,
}

/// Full training report.
#[derive(Clone, Debug)]
pub struct TrainReport {
    /// Which algorithm ran (CONV/MDS/MATDOT/SPACDC-DL).
    pub scheme: SchemeKind,
    /// Per-epoch curve.
    pub epochs: Vec<EpochStats>,
    /// Total wall-clock seconds.
    pub total_wall_s: f64,
    /// Final test accuracy.
    pub final_accuracy: f64,
    /// Total optimizer steps taken.
    pub steps: usize,
}

impl TrainReport {
    /// Wall-clock seconds until test accuracy first reached `target`
    /// (None if never reached) — the Fig. 4 readout.
    pub fn time_to_accuracy(&self, target: f64) -> Option<f64> {
        self.epochs
            .iter()
            .find(|e| e.accuracy >= target)
            .map(|e| e.wall_s)
    }
}

/// Train per Algorithm 2 under the configured scheme.
pub fn train(opts: &TrainerOptions) -> anyhow::Result<TrainReport> {
    let cfg = &opts.cfg;
    let dl = &cfg.dl;
    // Train and test share class templates (same distribution), with
    // disjoint sample streams.
    let train_data = Dataset::synthetic_with_templates(
        dl.train_examples,
        dl.layers[0],
        *dl.layers.last().unwrap(),
        cfg.seed,
        cfg.seed ^ 0x7121,
    );
    let test_data = Dataset::synthetic_with_templates(
        dl.test_examples,
        dl.layers[0],
        *dl.layers.last().unwrap(),
        cfg.seed,
        cfg.seed ^ 0x7E57,
    );
    let mut net = Network::new(&dl.layers, cfg.seed ^ 0x11E7);

    let mut master = {
        let builder = crate::coordinator::MasterBuilder::new(cfg.clone());
        match &opts.executor {
            Some(e) => builder.executor(e.clone()).build()?,
            None => builder.build()?,
        }
    };
    // One session lane serves the whole training run (DESIGN.md §12):
    // each backward product is fed through `Service::round` the moment
    // the step needs it, so nothing is ever materialized encoded —
    // memory stays flat no matter how many epochs or batches stream
    // through. (Gradient steps are sequentially dependent: step t's
    // product uses step t-1's weights, so the lane runs synchronous —
    // lookahead is impossible by construction, not by buffering.)
    let speculate = master.speculation();
    let mut service = master.service(ServiceConfig { global_inflight: 1, speculate });
    let session = service.open("dl-trainer", SessionOptions::default());

    let t0 = Instant::now();
    let mut epochs = Vec::with_capacity(dl.epochs);
    let mut steps = 0usize;
    'training: for epoch in 0..dl.epochs {
        let order = train_data.epoch_order(cfg.seed, epoch);
        let mut epoch_loss = 0.0;
        let mut batches = 0usize;
        for chunk in order.chunks(dl.batch_size) {
            if chunk.len() < dl.batch_size {
                continue; // keep coded shapes fixed (artifact-friendly)
            }
            let (x, y) = train_data.batch(chunk);
            let fwd = net.forward(&x);
            let mut mm_err: Option<anyhow::Error> = None;
            let (loss, grads) = net.backward_with(&fwd, &y, &mut |_l, w, delta| {
                match coded_backward_product(&mut service, session, w, delta) {
                    Ok(h) => h,
                    Err(e) => {
                        mm_err = Some(e);
                        // Fallback keeps shapes consistent; the error is
                        // surfaced right after the step.
                        matmul(&w.transpose(), delta)
                    }
                }
            });
            if let Some(e) = mm_err {
                return Err(e);
            }
            net.apply(&grads, dl.learning_rate);
            epoch_loss += loss;
            batches += 1;
            steps += 1;
            if let Some(cap) = opts.max_steps {
                if steps >= cap {
                    epochs.push(EpochStats {
                        epoch,
                        loss: epoch_loss / batches.max(1) as f64,
                        accuracy: net.accuracy(&test_data, dl.batch_size),
                        wall_s: t0.elapsed().as_secs_f64(),
                    });
                    break 'training;
                }
            }
        }
        let accuracy = if opts.eval_each_epoch {
            net.accuracy(&test_data, dl.batch_size)
        } else {
            f64::NAN
        };
        epochs.push(EpochStats {
            epoch,
            loss: epoch_loss / batches.max(1) as f64,
            accuracy,
            wall_s: t0.elapsed().as_secs_f64(),
        });
    }

    service.finish();
    let final_accuracy = net.accuracy(&test_data, dl.batch_size);
    Ok(TrainReport {
        scheme: cfg.scheme,
        epochs,
        total_wall_s: t0.elapsed().as_secs_f64(),
        final_accuracy,
        steps,
    })
}

/// The Eq. (23) product through the coded fabric:
/// `H = Θᵀ·δ`, expressed as one [`CodedTask::PairProduct`] so the same
/// line serves all eight schemes — MatDot encodes both operands, the
/// row-partition schemes encode Θᵀ and broadcast δ, and the decode
/// returns the full stacked product either way. Fed through the
/// trainer's persistent session lane, one round at a time.
fn coded_backward_product(
    service: &mut Service<'_>,
    session: SessionId,
    w: &Matrix,
    delta: &Matrix,
) -> anyhow::Result<Matrix> {
    let task = CodedTask::pair_product(w.transpose(), delta.clone());
    let out = service.round(session, task)?;
    Ok(out.blocks.into_iter().next().expect("pair product decodes to one matrix"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg(scheme: SchemeKind) -> SystemConfig {
        let mut cfg = SystemConfig::default();
        cfg.workers = 10;
        cfg.partitions = 2;
        cfg.colluders = 2;
        cfg.stragglers = 2;
        cfg.scheme = scheme;
        cfg.delay.base_service_s = 0.0;
        cfg.dl.layers = vec![32, 24, 16, 4];
        cfg.dl.batch_size = 32;
        cfg.dl.epochs = 4;
        cfg.dl.train_examples = 512;
        cfg.dl.test_examples = 128;
        cfg.dl.learning_rate = 0.1;
        cfg
    }

    #[test]
    fn spacdc_dl_converges() {
        let report = train(&TrainerOptions::new(tiny_cfg(SchemeKind::Spacdc))).unwrap();
        assert_eq!(report.epochs.len(), 4);
        assert!(
            report.final_accuracy > 0.6,
            "SPACDC-DL accuracy {}",
            report.final_accuracy
        );
        // Loss should decrease from first to last epoch.
        assert!(report.epochs.last().unwrap().loss < report.epochs[0].loss);
    }

    #[test]
    fn conv_dl_converges_exactly() {
        let report = train(&TrainerOptions::new(tiny_cfg(SchemeKind::Uncoded))).unwrap();
        assert!(report.final_accuracy > 0.7, "CONV-DL accuracy {}", report.final_accuracy);
    }

    #[test]
    fn mds_dl_converges_exactly() {
        let report = train(&TrainerOptions::new(tiny_cfg(SchemeKind::Mds))).unwrap();
        assert!(report.final_accuracy > 0.7, "MDS-DL accuracy {}", report.final_accuracy);
    }

    #[test]
    fn matdot_dl_converges_exactly() {
        let report = train(&TrainerOptions::new(tiny_cfg(SchemeKind::MatDot))).unwrap();
        assert!(report.final_accuracy > 0.7, "MATDOT-DL accuracy {}", report.final_accuracy);
    }

    #[test]
    fn max_steps_caps_training() {
        let mut opts = TrainerOptions::new(tiny_cfg(SchemeKind::Spacdc));
        opts.max_steps = Some(3);
        let report = train(&opts).unwrap();
        assert_eq!(report.steps, 3);
    }

    #[test]
    fn time_to_accuracy_readout() {
        let report = train(&TrainerOptions::new(tiny_cfg(SchemeKind::Uncoded))).unwrap();
        if report.final_accuracy >= 0.5 {
            let t = report.time_to_accuracy(0.5);
            assert!(t.is_some());
            assert!(t.unwrap() <= report.total_wall_s + 1e-9);
        }
        assert!(report.time_to_accuracy(1.01).is_none());
    }
}
