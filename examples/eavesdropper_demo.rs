//! Security & privacy demo: what the adversaries actually see.
//!
//! 1. **Eavesdropper** — taps every master↔worker link; we run the same
//!    round with `TransportSecurity::Plain` vs `MeaEcc` and report the
//!    correlation between the wire payloads and the true shares.
//! 2. **Colluders** — T workers pool their decrypted shares and run the
//!    best single-share linear inversion; we report the reconstruction
//!    error at increasing mask scales (the DESIGN.md §3 trade-off).

use spacdc::coding::{BlockCode, CodeParams, CodedTask, Spacdc};
use spacdc::config::{SchemeKind, SystemConfig, TransportSecurity};
use spacdc::coordinator::MasterBuilder;
use spacdc::matrix::{split_rows, Matrix};
use spacdc::rng::rng_from_seed;
use spacdc::runtime::WorkerOp;
use spacdc::sim::EavesdropLog;
use std::sync::Arc;

fn eavesdrop_run(security: TransportSecurity) -> anyhow::Result<(f64, usize)> {
    let mut cfg = SystemConfig::default();
    cfg.workers = 12;
    cfg.partitions = 3;
    cfg.colluders = 2;
    cfg.stragglers = 2;
    cfg.scheme = SchemeKind::Bacc; // deterministic encode → reconstructible
    cfg.security = security;
    cfg.delay.base_service_s = 0.0;
    cfg.seed = 0xEA7;
    let tap = Arc::new(EavesdropLog::new());
    let mut master = MasterBuilder::new(cfg).eavesdropper(Arc::clone(&tap)).build()?;
    let mut rng = rng_from_seed(5);
    let x = Matrix::random_gaussian(24, 16, 0.0, 1.0, &mut rng);
    master.run(CodedTask::block_map(WorkerOp::Identity, x.clone()))?;
    // Reproduce the true shares (BACC encode is deterministic).
    let scheme = spacdc::coding::Bacc::new(CodeParams::new(12, 3, 0));
    let enc = scheme.encode_blocks(&x, 1, &mut rng_from_seed(0))?;
    Ok((tap.downlink_correlation(&enc.shares), tap.count()))
}

fn main() -> anyhow::Result<()> {
    println!("== eavesdropper on the wire ==\n");
    let (plain_corr, n1) = eavesdrop_run(TransportSecurity::Plain)?;
    let (sealed_corr, n2) = eavesdrop_run(TransportSecurity::MeaEcc)?;
    println!("plain transport : {n1} messages captured, share correlation {plain_corr:.3}");
    println!("MEA-ECC sealed  : {n2} messages captured, share correlation {sealed_corr:.3}");
    println!("→ with MEA-ECC the tap learns (statistically) nothing.\n");

    println!("== T colluding workers ==\n");
    println!("{:<12} {:>22} {:>18}", "mask_scale", "colluder attack err", "decode rel-err");
    for &scale in &[0.5f32, 1.0, 2.0, 4.0] {
        let k = 4;
        let t = 3;
        let scheme = Spacdc::with_mask_scale(CodeParams::new(30, k, t), scale);
        let mut rng = rng_from_seed(0xC011);
        let x = Matrix::random_gaussian(64, 32, 0.0, 1.0, &mut rng);
        let enc = scheme.encode_blocks(&x, 1, &mut rng)?;
        let (blocks, _) = split_rows(&x, k);
        // Best single-share inversion across the T colluders & K blocks.
        let (data_pos, _) = Spacdc::node_layout(k, t);
        let betas = scheme.betas();
        let signs: Vec<u32> = (0..(k + t) as u32).collect();
        let mut attack = f64::INFINITY;
        for j in 0..t {
            let w = spacdc::coding::interp::berrut_weights(&betas, &signs, enc.ctx.alphas[j]);
            for (b, block) in blocks.iter().enumerate() {
                let wb = w[data_pos[b]];
                if wb.abs() > 1e-6 {
                    attack =
                        attack.min(enc.shares[j].scale(1.0 / wb as f32).rel_error(block));
                }
            }
        }
        // Decode quality at 27/30 returns for the same scale.
        let results: Vec<(usize, Matrix)> =
            (0..27).map(|i| (i, enc.shares[i].clone())).collect();
        let decoded = scheme.decode_blocks(&enc.ctx, &results)?;
        let err = decoded
            .iter()
            .zip(&blocks)
            .map(|(d, b)| d.rel_error(b))
            .fold(0.0f64, f64::max);
        println!("{scale:<12} {attack:>22.4} {err:>18.4}");
    }
    println!(
        "\nnote: the paper's Theorem 2 ITP is exact over a finite field; \
         over ℝ the mask amplitude sets the leakage bound (DESIGN.md §3)."
    );
    Ok(())
}
