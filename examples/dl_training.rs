//! End-to-end SPACDC-DL training driver — the repo's headline
//! validation run (see DESIGN.md §4 for the experiment index).
//!
//! Trains the §VI DNN (784-256-128-10, ≈236k parameters — the paper's
//! MNIST-scale workload) on the synthetic MNIST-like dataset with the
//! full stack engaged:
//!
//! * every hidden-layer backward product runs as a coded round through
//!   the master/worker fabric (SPACDC encode → MEA-ECC seal → dispatch →
//!   decode from the non-straggler returns);
//! * workers execute through the PJRT artifacts
//!   (`rightmul_64x128x64`, `rightmul_32x10x64`) when built;
//! * stragglers are injected (S=3 of N=30 at 5×).
//!
//! Logs the loss curve + test accuracy per epoch, then repeats with
//! CONV-DL for the headline speedup comparison.

use spacdc::config::{SchemeKind, SystemConfig, TransportSecurity};
use spacdc::dl::{train, TrainerOptions};
use spacdc::metrics::{names, MetricsRegistry};
use spacdc::runtime::{Executor, RuntimeService};
use std::path::Path;
use std::sync::Arc;

fn base_cfg() -> SystemConfig {
    let mut cfg = SystemConfig::default(); // N=30, T=3, K=4
    cfg.stragglers = 3;
    cfg.delay.base_service_s = 0.002; // simulated cluster service time
    cfg.delay.straggler_factor = 5.0;
    cfg.dl.layers = vec![784, 256, 128, 10];
    cfg.dl.batch_size = 64;
    cfg.dl.train_examples = 2048;
    cfg.dl.test_examples = 512;
    cfg.dl.epochs = 5;
    cfg.dl.learning_rate = 0.08;
    cfg.seed = 0xE2E;
    cfg
}

fn main() -> anyhow::Result<()> {
    let metrics = Arc::new(MetricsRegistry::new());
    // Keep the service handle in scope: it owns the runtime thread and
    // joins it on drop at the end of `main` (no `std::mem::forget` leak).
    let runtime: Option<RuntimeService> = match RuntimeService::start(Path::new("artifacts")) {
        Ok(svc) => {
            println!("PJRT runtime: {} artifacts", svc.handle().keys().len());
            Some(svc)
        }
        Err(_) => {
            println!("PJRT runtime unavailable (run `make artifacts`); native kernels");
            None
        }
    };
    let executor =
        runtime.as_ref().map(|svc| Executor::with_runtime(svc.handle(), Arc::clone(&metrics)));

    // --- PJRT demonstration epoch --------------------------------------
    // One epoch with worker tasks on the compiled-artifact path, proving
    // the three layers compose. (The PJRT service serializes FFI calls on
    // one thread, so the *timing* comparison below runs on the native
    // kernels, which execute in parallel across worker threads like a
    // real cluster.)
    if let Some(exec) = &executor {
        let mut demo = base_cfg();
        demo.scheme = SchemeKind::Spacdc;
        demo.dl.epochs = 1;
        let mut opts = TrainerOptions::new(demo);
        opts.executor = Some(exec.clone());
        let r = train(&opts)?;
        println!(
            "PJRT demo epoch: loss {:.4}, accuracy {:.3}, {} PJRT executions",
            r.epochs[0].loss,
            r.epochs[0].accuracy,
            metrics.get(names::PJRT_EXECUTIONS)
        );
    }

    // --- SPACDC-DL ---------------------------------------------------
    let mut cfg = base_cfg();
    cfg.scheme = SchemeKind::Spacdc;
    cfg.security = TransportSecurity::MeaEcc;
    println!(
        "\nSPACDC-DL: {} parameters, N={}, S={}, T={}, K={}",
        spacdc::dl::Network::new(&cfg.dl.layers, 0).parameter_count(),
        cfg.workers,
        cfg.stragglers,
        cfg.colluders,
        cfg.partitions
    );
    let opts = TrainerOptions::new(cfg);
    let spacdc_report = train(&opts)?;
    println!("epoch  loss      accuracy  wall(s)");
    for e in &spacdc_report.epochs {
        println!("{:>5}  {:<8.4}  {:<8.4}  {:<8.2}", e.epoch, e.loss, e.accuracy, e.wall_s);
    }
    println!(
        "PJRT executions: {}, native: {}",
        metrics.get(names::PJRT_EXECUTIONS),
        metrics.get(names::NATIVE_EXECUTIONS)
    );

    // --- CONV-DL baseline ---------------------------------------------
    let mut conv_cfg = base_cfg();
    conv_cfg.scheme = SchemeKind::Uncoded;
    conv_cfg.security = TransportSecurity::Plain;
    println!("\nCONV-DL baseline (same workload, waits for all workers):");
    let conv_opts = TrainerOptions::new(conv_cfg);
    let conv_report = train(&conv_opts)?;
    println!("epoch  loss      accuracy  wall(s)");
    for e in &conv_report.epochs {
        println!("{:>5}  {:<8.4}  {:<8.4}  {:<8.2}", e.epoch, e.loss, e.accuracy, e.wall_s);
    }

    // --- headline ------------------------------------------------------
    let saving = 100.0 * (1.0 - spacdc_report.total_wall_s / conv_report.total_wall_s);
    println!("\n=== headline ===");
    println!(
        "SPACDC-DL: {:.2}s to accuracy {:.3} | CONV-DL: {:.2}s to accuracy {:.3}",
        spacdc_report.total_wall_s,
        spacdc_report.final_accuracy,
        conv_report.total_wall_s,
        conv_report.final_accuracy
    );
    println!(
        "training-time saving: {saving:.1}% (paper: ~52–65% at S ∈ {{5,7}}, \
         ~this range at S=3 with encryption on)"
    );
    Ok(())
}
