//! Straggler sweep — the Fig. 3 scenario grid at example scale.
//!
//! Sweeps S ∈ {0, 3, 5, 7} for all four DL algorithms and prints the
//! training time matrix plus SPACDC's saving column. A fast, inspectable
//! version of `cargo bench --bench fig3_training_time`.

use spacdc::config::{SchemeKind, SystemConfig, TransportSecurity};
use spacdc::dl::{train, TrainerOptions};

fn main() -> anyhow::Result<()> {
    let schemes =
        [SchemeKind::Uncoded, SchemeKind::Mds, SchemeKind::MatDot, SchemeKind::Spacdc];
    let scenarios = [0usize, 3, 5, 7];
    const STEPS: usize = 8;

    println!("training-time sweep: N=30, T=3, {STEPS} steps, 5x stragglers\n");
    println!("{:<12} {:>8} {:>8} {:>8} {:>8}", "scheme", "S=0", "S=3", "S=5", "S=7");
    let mut wall = vec![vec![0.0; scenarios.len()]; schemes.len()];
    for (si, &scheme) in schemes.iter().enumerate() {
        for (ci, &s) in scenarios.iter().enumerate() {
            let mut cfg = SystemConfig::default();
            cfg.scheme = scheme;
            cfg.stragglers = s;
            cfg.security = if scheme == SchemeKind::Spacdc {
                TransportSecurity::MeaEcc
            } else {
                TransportSecurity::Plain
            };
            cfg.delay.base_service_s = 0.002;
            cfg.dl.layers = vec![256, 128, 64, 10];
            cfg.dl.train_examples = 512;
            cfg.dl.test_examples = 128;
            cfg.dl.epochs = 1;
            cfg.seed = 0x57EE9;
            let mut opts = TrainerOptions::new(cfg);
            opts.max_steps = Some(STEPS);
            opts.eval_each_epoch = false;
            wall[si][ci] = train(&opts)?.total_wall_s;
        }
        println!(
            "{:<12} {:>8.3} {:>8.3} {:>8.3} {:>8.3}",
            scheme.name(),
            wall[si][0],
            wall[si][1],
            wall[si][2],
            wall[si][3]
        );
    }
    println!("\nSPACDC saving vs CONV:");
    for (ci, &s) in scenarios.iter().enumerate() {
        println!("  S={s}: {:.1}%", 100.0 * (1.0 - wall[3][ci] / wall[0][ci]));
    }
    Ok(())
}
