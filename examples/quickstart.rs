//! Quickstart: one secure, private, straggler-tolerant coded round.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! Builds the default cluster (N=30 workers, T=3 colluders tolerated,
//! S=3 stragglers injected), distributes the paper's running task
//! `f(X) = X·Xᵀ` over K=4 row-blocks with SPACDC + MEA-ECC as one typed
//! [`CodedTask`], and decodes the approximation from the non-straggler
//! returns. Workers execute on the PJRT artifact path when `artifacts/`
//! is present.

use spacdc::coding::CodedTask;
use spacdc::config::SystemConfig;
use spacdc::coordinator::MasterBuilder;
use spacdc::matrix::{gram, split_rows, Matrix};
use spacdc::metrics::{names, MetricsRegistry};
use spacdc::rng::rng_from_seed;
use spacdc::runtime::{Executor, RuntimeService, WorkerOp};
use std::path::Path;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let cfg = SystemConfig::default(); // N=30, T=3, S=3, K=4, SPACDC+MEA-ECC
    println!(
        "cluster: N={} workers, K={} partitions, T={} colluders, S={} stragglers",
        cfg.workers, cfg.partitions, cfg.colluders, cfg.stragglers
    );

    // PJRT runtime if artifacts are built; native kernels otherwise. The
    // service handle stays in scope for the whole run — dropping it at
    // the end of `main` shuts the runtime thread down cleanly (no
    // `std::mem::forget` leak).
    let metrics = Arc::new(MetricsRegistry::new());
    let runtime: Option<RuntimeService> = match RuntimeService::start(Path::new(&cfg.artifacts_dir))
    {
        Ok(svc) => {
            println!("PJRT runtime: {} artifacts loaded", svc.handle().keys().len());
            Some(svc)
        }
        Err(_) => {
            println!("PJRT runtime: artifacts not built; using native kernels");
            None
        }
    };
    let executor = match &runtime {
        Some(svc) => Executor::with_runtime(svc.handle(), Arc::clone(&metrics)),
        None => Executor::native(Arc::clone(&metrics)),
    };

    let mut master = MasterBuilder::new(cfg.clone())
        .executor(executor)
        .metrics(Arc::clone(&metrics))
        .build()?;

    // The quickstart task: Gram of a 512×256 dataset. Each share is
    // 128×256 — exactly the `gram_128x256` artifact shape.
    let mut rng = rng_from_seed(42);
    let x = Matrix::random_gaussian(512, 256, 0.0, 1.0, &mut rng);
    let out = master.run(CodedTask::block_map(WorkerOp::Gram, x.clone()))?;

    println!(
        "\nround complete in {:.1} ms using {} of {} worker results",
        out.wall.as_secs_f64() * 1e3,
        out.results_used,
        cfg.workers
    );
    let (blocks, _) = split_rows(&x, cfg.partitions);
    for (i, (decoded, block)) in out.blocks.iter().zip(&blocks).enumerate() {
        println!("  block {i}: rel error {:.4}", decoded.rel_error(&gram(block)));
    }
    println!(
        "\nexecution paths: {} PJRT, {} native",
        metrics.get(names::PJRT_EXECUTIONS),
        metrics.get(names::NATIVE_EXECUTIONS)
    );
    println!("{}", metrics.report());
    Ok(())
}
