//! The paper's §V-A illustrating example, phase by phase:
//! `f(X) = X·Xᵀ` with N=8 workers, K=2 partitions, S=T=1.
//!
//! Walks the three SPACDC phases explicitly — encode (Eq. (14)),
//! MEA-ECC transport (§IV-B), worker compute, Berrut decode (Eq. (15)) —
//! using the coding/ECC layers directly, without the coordinator, so the
//! protocol is visible end to end.

use spacdc::coding::{BlockCode, CodeParams, Spacdc};
use spacdc::ecc::{sim_curve, KeyPair, MaskMode, MeaEcc};
use spacdc::matrix::{gram, split_rows, Matrix};
use spacdc::rng::rng_from_seed;

fn main() -> anyhow::Result<()> {
    let (n, k, t, s) = (8usize, 2usize, 1usize, 1usize);
    println!("§V-A example: f(X)=XXᵀ, N={n}, K={k}, T={t}, S={s}\n");
    let mut rng = rng_from_seed(7);

    // Keys: master + 8 workers (§IV-B steps 1–2).
    let curve = sim_curve();
    let master_keys = KeyPair::generate(&curve, &mut rng);
    let worker_keys: Vec<_> = (0..n).map(|_| KeyPair::generate(&curve, &mut rng)).collect();
    let mea = MeaEcc::new(curve, MaskMode::Keystream);
    println!("[keys] master + {n} worker key pairs generated; ECDH share keys agree");

    // Phase 1 — data process (Eq. (14)): split K=2, add T=1 mask, encode.
    let x = Matrix::random_gaussian(16, 12, 0.0, 1.0, &mut rng);
    let scheme = Spacdc::new(CodeParams::new(n, k, t));
    let encoded = scheme.encode_blocks(&x, 2, &mut rng)?;
    println!("[encode] X(16x12) → {} shares of {:?}", n, encoded.shares[0].shape());

    // Transport: seal share j for worker j.
    let sealed: Vec<_> = encoded
        .shares
        .iter()
        .enumerate()
        .map(|(j, sh)| mea.encrypt(sh, &worker_keys[j].public(), &mut rng))
        .collect();
    println!("[seal]   {} ciphertexts (ephemeral point + masked payload each)", sealed.len());

    // Phase 2 — task computing. Worker `s` (index 7) straggles and never
    // returns; the rest decrypt, compute the Gram task, re-seal.
    let mut returned = Vec::new();
    for j in 0..n - s {
        let share = mea.decrypt(&sealed[j], &worker_keys[j]);
        let result = gram(&share);
        let back = mea.encrypt(&result, &master_keys.public(), &mut rng);
        returned.push((j, back));
    }
    println!("[compute] {} workers returned; {} straggler(s) dropped", returned.len(), s);

    // Phase 3 — result recovering (Eq. (15)).
    let results: Vec<(usize, Matrix)> = returned
        .iter()
        .map(|(j, c)| (*j, mea.decrypt(c, &master_keys)))
        .collect();
    let decoded = scheme.decode_blocks(&encoded.ctx, &results)?;

    let (blocks, _) = split_rows(&x, k);
    println!("\n[decode] approximation quality per block:");
    for (i, (d, b)) in decoded.iter().zip(&blocks).enumerate() {
        println!("  f(X_{i}) rel error: {:.4}", d.rel_error(&gram(b)));
    }
    println!("\nno recovery threshold was enforced — any non-empty return set decodes.");
    Ok(())
}
